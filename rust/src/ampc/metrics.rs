//! Cost accounting: comparisons, per-worker busy time, shuffle bytes —
//! plus the per-job phase-span collector (`crate::obs`), so every report
//! carries a self-profile of where its seconds went.

use crate::obs::{PhaseReport, Phases};
use crate::util::fault::FaultPlan;
use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};

/// Shared, thread-safe cost counters for one graph-building job.
#[derive(Debug)]
pub struct CostLedger {
    /// Per-worker busy nanoseconds ("total running time" contributors).
    busy_nanos: Vec<AtomicU64>,
    comparisons: AtomicU64,
    sketch_evals: AtomicU64,
    edges_emitted: AtomicU64,
    shuffle_bytes: AtomicU64,
    dht_lookups: AtomicU64,
    dht_bytes: AtomicU64,
    /// The job's fault schedule. Riding on the ledger (which already flows
    /// through every cluster primitive) lets `dht`/`shuffle` consult the
    /// plan without signature churn; the inert plan costs one branch.
    faults: FaultPlan,
    task_retries: AtomicU64,
    injected_crashes: AtomicU64,
    injected_delays: AtomicU64,
    corruption_retries: AtomicU64,
    wave_restarts: AtomicU64,
    stragglers: AtomicU64,
    /// Phase-span collector for this job. Riding on the ledger (like the
    /// fault plan) gives every pipeline stage span access without
    /// signature churn; purely additive — spans never feed back into any
    /// cost counter (the bit-identity contract).
    phases: Phases,
}

impl CostLedger {
    /// Ledger for `workers` workers, no fault schedule.
    pub fn new(workers: usize) -> CostLedger {
        CostLedger::with_faults(workers, FaultPlan::none())
    }

    /// Ledger for `workers` workers carrying a fault schedule.
    pub fn with_faults(workers: usize, faults: FaultPlan) -> CostLedger {
        CostLedger {
            busy_nanos: (0..workers.max(1)).map(|_| AtomicU64::new(0)).collect(),
            comparisons: AtomicU64::new(0),
            sketch_evals: AtomicU64::new(0),
            edges_emitted: AtomicU64::new(0),
            shuffle_bytes: AtomicU64::new(0),
            dht_lookups: AtomicU64::new(0),
            dht_bytes: AtomicU64::new(0),
            faults,
            task_retries: AtomicU64::new(0),
            injected_crashes: AtomicU64::new(0),
            injected_delays: AtomicU64::new(0),
            corruption_retries: AtomicU64::new(0),
            wave_restarts: AtomicU64::new(0),
            stragglers: AtomicU64::new(0),
            phases: Phases::new(),
        }
    }

    /// The job's fault schedule (the inert plan when none was configured).
    pub fn faults(&self) -> &FaultPlan {
        &self.faults
    }

    /// The job's phase-span collector (`crate::obs`): enter spans via
    /// `ledger.phases().enter("name")`; the aggregate lands in
    /// [`CostReport::phases`].
    pub fn phases(&self) -> &Phases {
        &self.phases
    }

    /// Record one task re-attempt (after an injected crash or a real panic).
    #[inline]
    pub fn add_task_retry(&self) {
        self.task_retries.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one injected task crash.
    #[inline]
    pub fn add_injected_crash(&self) {
        self.injected_crashes.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one injected task delay.
    #[inline]
    pub fn add_injected_delay(&self) {
        self.injected_delays.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one checksum-failure retry (shuffle partition or DHT batch).
    #[inline]
    pub fn add_corruption_retry(&self) {
        self.corruption_retries.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one wave restart (a task exhausted its in-place retry budget
    /// and the builder re-ran the whole wave from its checkpoint).
    #[inline]
    pub fn add_wave_restart(&self) {
        self.wave_restarts.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one straggler re-execution.
    #[inline]
    pub fn add_straggler(&self) {
        self.stragglers.fetch_add(1, Ordering::Relaxed);
    }

    /// Snapshot of the fault/recovery counters.
    pub fn fault_counters(&self) -> FaultCounters {
        FaultCounters {
            task_retries: self.task_retries.load(Ordering::Relaxed),
            injected_crashes: self.injected_crashes.load(Ordering::Relaxed),
            injected_delays: self.injected_delays.load(Ordering::Relaxed),
            corruption_retries: self.corruption_retries.load(Ordering::Relaxed),
            wave_restarts: self.wave_restarts.load(Ordering::Relaxed),
            stragglers: self.stragglers.load(Ordering::Relaxed),
        }
    }

    /// Number of workers this ledger tracks.
    pub fn workers(&self) -> usize {
        self.busy_nanos.len()
    }

    /// Charge busy time to a worker.
    #[inline]
    pub fn add_busy(&self, worker: usize, nanos: u64) {
        self.busy_nanos[worker % self.busy_nanos.len()].fetch_add(nanos, Ordering::Relaxed);
    }

    /// Charge busy time from an in-repetition *inner* worker (the spare
    /// cores a wave grants when it has fewer repetitions than machines).
    ///
    /// Accounting model: `Cluster::map_timed` already charges a
    /// repetition's full wall time to one worker slot, and inner worker 0's
    /// span is concurrent with (and bounded by) that wall charge — so only
    /// workers ≥ 1 add machine-seconds. With this, Σ busy reflects the
    /// machine-seconds a real fleet would spend instead of under-reporting
    /// every multi-core repetition as one machine.
    #[inline]
    pub fn add_inner_busy(&self, worker: usize, nanos: u64) {
        if worker > 0 {
            self.add_busy(worker, nanos);
        }
    }

    /// Record `n` pairwise similarity evaluations.
    #[inline]
    pub fn add_comparisons(&self, n: u64) {
        self.comparisons.fetch_add(n, Ordering::Relaxed);
    }

    /// Record `n` LSH sketch evaluations.
    #[inline]
    pub fn add_sketches(&self, n: u64) {
        self.sketch_evals.fetch_add(n, Ordering::Relaxed);
    }

    /// Record `n` emitted edges (pre-dedup).
    #[inline]
    pub fn add_edges(&self, n: u64) {
        self.edges_emitted.fetch_add(n, Ordering::Relaxed);
    }

    /// Record shuffle I/O bytes.
    #[inline]
    pub fn add_shuffle_bytes(&self, n: u64) {
        self.shuffle_bytes.fetch_add(n, Ordering::Relaxed);
    }

    /// Record a DHT lookup of `bytes` payload.
    #[inline]
    pub fn add_dht_lookup(&self, bytes: u64) {
        self.dht_lookups.fetch_add(1, Ordering::Relaxed);
        self.dht_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Total comparisons so far.
    pub fn comparisons(&self) -> u64 {
        self.comparisons.load(Ordering::Relaxed)
    }

    /// Sum of per-worker busy time, seconds — the paper's "total running
    /// time ... over all machines".
    pub fn total_time(&self) -> f64 {
        self.busy_nanos
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .sum::<u64>() as f64
            / 1e9
    }

    /// Immutable snapshot.
    pub fn report(&self, real_time: f64) -> CostReport {
        CostReport {
            workers: self.busy_nanos.len(),
            comparisons: self.comparisons.load(Ordering::Relaxed),
            sketch_evals: self.sketch_evals.load(Ordering::Relaxed),
            edges_emitted: self.edges_emitted.load(Ordering::Relaxed),
            shuffle_bytes: self.shuffle_bytes.load(Ordering::Relaxed),
            dht_lookups: self.dht_lookups.load(Ordering::Relaxed),
            dht_bytes: self.dht_bytes.load(Ordering::Relaxed),
            total_time: self.total_time(),
            real_time,
            simd_backend: crate::util::simd::active().name(),
            snapshot: None,
            faults: self.fault_counters(),
            phases: self.phases.report(),
        }
    }
}

/// Fault-injection and recovery counters for one job. All zero on a clean
/// run with no schedule; nonzero entries say which recovery paths fired
/// (and were absorbed — a report with nonzero counters still describes
/// bit-identical output, that's the contract).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultCounters {
    /// In-place task re-attempts (injected crashes + caught real panics).
    pub task_retries: u64,
    /// Injected crashes served by the schedule.
    pub injected_crashes: u64,
    /// Injected straggler delays served by the schedule.
    pub injected_delays: u64,
    /// Checksum-failure retries (shuffle partitions, DHT batches).
    pub corruption_retries: u64,
    /// Whole-wave restarts from the builder's per-repetition checkpoint.
    pub wave_restarts: u64,
    /// Straggler re-executions by the speculative pass.
    pub stragglers: u64,
}

impl FaultCounters {
    /// True if any recovery path fired.
    pub fn any(&self) -> bool {
        *self != FaultCounters::default()
    }

    /// JSON object for experiment/serving reports.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("task_retries", Json::from(self.task_retries)),
            ("injected_crashes", Json::from(self.injected_crashes)),
            ("injected_delays", Json::from(self.injected_delays)),
            ("corruption_retries", Json::from(self.corruption_retries)),
            ("wave_restarts", Json::from(self.wave_restarts)),
            ("stragglers", Json::from(self.stragglers)),
        ])
    }
}

/// Size/memory telemetry of a serving snapshot — router tables, CSR
/// adjacency, cached sketch-state tables. `StarsBuilder::build_indexed`
/// attaches one to its [`CostReport`] so capacity planning is tracked in
/// the same reports as build costs (bytes are heap estimates of the live
/// arrays, not allocator-exact).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SnapshotStats {
    /// Indexed points.
    pub points: usize,
    /// Undirected star-graph edges in the snapshot CSR.
    pub edges: usize,
    /// Routing repetitions.
    pub router_reps: usize,
    /// Live entry points across all routing tables.
    pub router_entries: usize,
    /// Router heap bytes (entry arrays + key tables).
    pub router_bytes: usize,
    /// CSR heap bytes (offsets + neighbors + weights).
    pub csr_bytes: usize,
    /// Cached sketch-state table bytes (hyperplanes, per-token tables).
    pub state_table_bytes: usize,
    /// Whether the snapshot carries an SQ8 table for quantized first-pass
    /// scoring (`ServeConfig::quantized`).
    pub quantized: bool,
    /// Exact-rescore width multiplier of the quantized path (`c = k ·
    /// rescore_factor` survivors per query); 0 when not quantized.
    pub rescore_factor: usize,
    /// SQ8 table heap bytes (i8 codes + per-row scales); 0 when not
    /// quantized.
    pub quant_bytes: usize,
    /// Bytes per row of the first-pass scoring storage: `dim + 4` (codes
    /// + scale) when quantized, `4 · dim` (the dense f32 row) otherwise —
    /// the ~4× row-storage reduction shows up here.
    pub bytes_per_row: usize,
}

impl SnapshotStats {
    /// JSON object for experiment/serving reports.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("points", Json::from(self.points)),
            ("edges", Json::from(self.edges)),
            ("router_reps", Json::from(self.router_reps)),
            ("router_entries", Json::from(self.router_entries)),
            ("router_bytes", Json::from(self.router_bytes)),
            ("csr_bytes", Json::from(self.csr_bytes)),
            ("state_table_bytes", Json::from(self.state_table_bytes)),
            ("quantized", Json::from(self.quantized)),
            ("rescore_factor", Json::from(self.rescore_factor)),
            ("quant_bytes", Json::from(self.quant_bytes)),
            ("bytes_per_row", Json::from(self.bytes_per_row)),
        ])
    }
}

/// Snapshot of a job's costs — the row schema of the paper's tables.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CostReport {
    /// Worker count.
    pub workers: usize,
    /// Pairwise similarity evaluations (Figure 1's metric).
    pub comparisons: u64,
    /// LSH sketch evaluations.
    pub sketch_evals: u64,
    /// Edges emitted before dedup.
    pub edges_emitted: u64,
    /// Bytes moved by shuffle joins.
    pub shuffle_bytes: u64,
    /// DHT lookups performed.
    pub dht_lookups: u64,
    /// Bytes served by the DHT.
    pub dht_bytes: u64,
    /// Σ per-worker busy seconds (paper: total running time).
    pub total_time: f64,
    /// Wall-clock seconds (paper: real running time).
    pub real_time: f64,
    /// The SIMD backend the hot kernels dispatched to
    /// (`crate::util::simd::active().name()` — "scalar", "avx2" or "neon";
    /// empty on a defaulted report). Results never depend on it (the
    /// bit-identity contract), but throughput does, so every cost report
    /// records which lanes produced its numbers.
    pub simd_backend: &'static str,
    /// Serving-snapshot telemetry, when the job exported one
    /// (`StarsBuilder::build_indexed`).
    pub snapshot: Option<SnapshotStats>,
    /// Fault-injection/recovery counters; all zero on a clean run.
    pub faults: FaultCounters,
    /// Per-phase self-profile (`crate::obs` spans): path →
    /// {count, secs, busy_secs, bytes}. Purely additive — the `build`
    /// root reconciles with `real_time` and the Σ of `build/rep` spans
    /// with `total_time` to within accounting slack (asserted by
    /// `tests/obs.rs`).
    pub phases: PhaseReport,
}

impl CostReport {
    /// Convert to JSON for experiment reports.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("workers", Json::from(self.workers)),
            ("comparisons", Json::from(self.comparisons)),
            ("sketch_evals", Json::from(self.sketch_evals)),
            ("edges_emitted", Json::from(self.edges_emitted)),
            ("shuffle_bytes", Json::from(self.shuffle_bytes)),
            ("dht_lookups", Json::from(self.dht_lookups)),
            ("dht_bytes", Json::from(self.dht_bytes)),
            ("total_time_s", Json::from(self.total_time)),
            ("real_time_s", Json::from(self.real_time)),
            ("simd_backend", Json::from(self.simd_backend)),
        ];
        if let Some(s) = &self.snapshot {
            pairs.push(("snapshot", s.to_json()));
        }
        pairs.push(("faults", self.faults.to_json()));
        pairs.push(("phases", self.phases.to_json()));
        Json::obj(pairs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let l = CostLedger::new(4);
        l.add_comparisons(10);
        l.add_comparisons(5);
        l.add_busy(0, 1_000_000_000);
        l.add_busy(3, 500_000_000);
        l.add_edges(7);
        l.add_sketches(3);
        l.add_shuffle_bytes(100);
        l.add_dht_lookup(400);
        assert_eq!(l.comparisons(), 15);
        assert!((l.total_time() - 1.5).abs() < 1e-9);
        let r = l.report(2.0);
        assert_eq!(r.comparisons, 15);
        assert_eq!(r.edges_emitted, 7);
        assert_eq!(r.dht_lookups, 1);
        assert_eq!(r.real_time, 2.0);
    }

    #[test]
    fn inner_busy_skips_worker_zero() {
        // Worker 0's span is concurrent with the rep's wall charge; only
        // extra machines add to Σ busy.
        let l = CostLedger::new(4);
        l.add_inner_busy(0, 1_000_000_000);
        assert_eq!(l.total_time(), 0.0);
        l.add_inner_busy(2, 500_000_000);
        assert!((l.total_time() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn worker_index_wraps() {
        let l = CostLedger::new(2);
        l.add_busy(5, 100); // worker 5 % 2 = 1
        assert!(l.total_time() > 0.0);
    }

    #[test]
    fn fault_counters_accumulate_and_serialize() {
        let l = CostLedger::new(2);
        assert!(!l.fault_counters().any(), "clean ledger starts at zero");
        l.add_task_retry();
        l.add_injected_crash();
        l.add_injected_delay();
        l.add_corruption_retry();
        l.add_wave_restart();
        l.add_straggler();
        let c = l.fault_counters();
        assert!(c.any());
        assert_eq!(c.task_retries, 1);
        assert_eq!(c.injected_crashes, 1);
        assert_eq!(c.injected_delays, 1);
        assert_eq!(c.corruption_retries, 1);
        assert_eq!(c.wave_restarts, 1);
        assert_eq!(c.stragglers, 1);
        let j = l.report(0.0).to_json().to_string();
        let v = crate::util::json::parse(&j).unwrap();
        let f = v.get("faults").unwrap();
        assert_eq!(f.get("task_retries").unwrap().as_usize().unwrap(), 1);
        assert_eq!(f.get("wave_restarts").unwrap().as_usize().unwrap(), 1);
    }

    #[test]
    fn ledger_carries_its_plan() {
        let plan = crate::util::fault::FaultPlan::parse("seed=1,crash=0.5").unwrap();
        let l = CostLedger::with_faults(2, plan);
        assert_eq!(*l.faults(), plan);
        assert!(!CostLedger::new(1).faults().is_active());
    }

    #[test]
    fn report_to_json_parses() {
        let l = CostLedger::new(1);
        l.add_comparisons(3);
        let j = l.report(0.1).to_json().to_string();
        let v = crate::util::json::parse(&j).unwrap();
        assert_eq!(v.get("comparisons").unwrap().as_usize().unwrap(), 3);
        // Every report names the lanes that produced it.
        let backend = v.get("simd_backend").unwrap().as_str().unwrap().to_string();
        assert_eq!(backend, crate::util::simd::active().name());
    }
}
