//! TeraSort: sample-based range-partitioned parallel sort (paper §C.1).
//!
//! "The SortingLSH algorithm involves computing R sketches per point, then
//! sorting the nR total sketches ... we leverage the TeraSort algorithm."
//!
//! Structure: sample keys → choose `workers − 1` splitters → partition
//! records into per-worker ranges → sort ranges independently → concatenate.
//! This is the same algorithm Hadoop's TeraSort uses; here "machines" are
//! pool workers and the shuffle bytes are charged to the ledger.
//!
//! Records keyed by a packed `u64` — bucket keys, sketch keys, anything the
//! LSH layer emits — skip the sample/splitter machinery entirely:
//! [`terasort_u64`] rides `util::radix`'s pool-parallel digit pipeline
//! (per-worker histograms + prefix-scatter per byte, degenerate bytes mask-
//! skipped), the same code path SortingLSH's per-repetition sort uses. One
//! pipeline, two layers: the in-repetition sort and the shuffle join cannot
//! drift apart in either performance or tie behavior.

use super::metrics::CostLedger;
use crate::util::pool::parallel_chunks;
use crate::util::radix;
use crate::util::rng::Rng;

/// Sort `items` by `key` using sample-based range partitioning over
/// `workers` workers, charging shuffle bytes (one record write + read per
/// item) to `ledger`. Stable within ranges is not guaranteed (matching a
/// distributed shuffle).
pub fn terasort<T, K, F>(
    items: Vec<T>,
    workers: usize,
    record_bytes: u64,
    key: F,
    ledger: &CostLedger,
    seed: u64,
) -> Vec<T>
where
    T: Send + Sync + Clone,
    K: Ord + Clone + Send,
    F: Fn(&T) -> K + Sync,
{
    let n = items.len();
    let workers = workers.max(1);
    ledger.add_shuffle_bytes(2 * record_bytes * n as u64);
    if n <= 1 || workers == 1 {
        let mut items = items;
        items.sort_by(|a, b| key(a).cmp(&key(b)));
        return items;
    }

    // 1. Sample ~32 keys per worker and derive splitters.
    let mut rng = Rng::new(seed);
    let sample_size = (workers * 32).min(n);
    let mut sample: Vec<K> = (0..sample_size)
        .map(|_| key(&items[rng.below(n)]))
        .collect();
    sample.sort();
    let splitters: Vec<K> = (1..workers)
        .map(|w| sample[w * sample.len() / workers].clone())
        .collect();

    // 2. Partition into per-worker bins.
    let mut bins: Vec<Vec<T>> = (0..workers).map(|_| Vec::new()).collect();
    for item in items {
        let k = key(&item);
        // Index of first splitter > k == bin index.
        let bin = splitters.partition_point(|s| *s <= k);
        bins[bin].push(item);
    }

    // 3. Sort bins in parallel.
    let bins_ref = &bins;
    let sorted_bins = parallel_chunks(workers, workers, |_, range| {
        let mut out = Vec::new();
        for b in range {
            let mut bin = bins_ref[b].clone();
            bin.sort_by(|a, b| key(a).cmp(&key(b)));
            out.push((b, bin));
        }
        out
    });

    // 4. Concatenate in bin order.
    let mut flat: Vec<(usize, Vec<T>)> = sorted_bins.into_iter().flatten().collect();
    flat.sort_by_key(|(b, _)| *b);
    let mut out = Vec::with_capacity(n);
    for (_, bin) in flat {
        out.extend(bin);
    }
    out
}

/// [`terasort`] for records with a packed `u64` sort key, riding the radix
/// digit pipeline ([`radix::argsort_u64_par`]) instead of sample-based range
/// partitioning: per-worker digit histograms and prefix-scatters per live
/// byte, then one gather of the records into sorted order.
///
/// Unlike the generic [`terasort`], the order is fully deterministic —
/// **stable**: equal keys keep their input order (the radix permutation
/// breaks ties by position), independent of `workers`. Shuffle bytes are
/// charged exactly as [`terasort`] charges them (one record write + read
/// per item), and the radix passes' inner-worker busy spans land in Σ busy
/// via [`CostLedger::add_inner_busy`] — worker 0 rides the caller's wall
/// charge, like every other in-repetition parallel phase.
pub fn terasort_u64<T, F>(
    items: Vec<T>,
    workers: usize,
    record_bytes: u64,
    key: F,
    ledger: &CostLedger,
) -> Vec<T>
where
    T: Send,
    F: Fn(&T) -> u64,
{
    let n = items.len();
    ledger.add_shuffle_bytes(2 * record_bytes * n as u64);
    if n <= 1 {
        return items;
    }
    let keys: Vec<u64> = items.iter().map(&key).collect();
    let order = radix::argsort_u64_par_timed(&keys, workers.max(1), |w, nanos| {
        ledger.add_inner_busy(w, nanos)
    });
    let mut slots: Vec<Option<T>> = items.into_iter().map(Some).collect();
    order
        .into_iter()
        .map(|i| slots[i as usize].take().expect("radix order is a permutation"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{check, Gen};

    #[test]
    fn sorts_correctly() {
        check("terasort-vs-std", 25, |g: &mut Gen| {
            let n = g.usize_in(0, 3000);
            let items: Vec<u64> = (0..n).map(|_| g.usize_in(0, 10_000) as u64).collect();
            let ledger = CostLedger::new(4);
            let sorted = terasort(items.clone(), 4, 8, |x| *x, &ledger, 42);
            let mut want = items;
            want.sort();
            assert_eq!(sorted, want);
        });
    }

    #[test]
    fn charges_shuffle_bytes() {
        let ledger = CostLedger::new(2);
        let _ = terasort(vec![3u64, 1, 2], 2, 16, |x| *x, &ledger, 1);
        let r = ledger.report(0.0);
        assert_eq!(r.shuffle_bytes, 2 * 16 * 3);
    }

    #[test]
    fn handles_skewed_keys() {
        // All-equal keys land in one bin; must still terminate and sort.
        let items = vec![7u64; 5000];
        let ledger = CostLedger::new(8);
        let sorted = terasort(items.clone(), 8, 8, |x| *x, &ledger, 3);
        assert_eq!(sorted, items);
    }

    #[test]
    fn sorts_composite_keys() {
        let items: Vec<(u64, u32)> = vec![(2, 1), (1, 9), (2, 0), (1, 1)];
        let ledger = CostLedger::new(2);
        let sorted = terasort(items, 2, 12, |x| (x.0, x.1), &ledger, 5);
        assert_eq!(sorted, vec![(1, 1), (1, 9), (2, 0), (2, 1)]);
    }

    #[test]
    fn terasort_u64_matches_stable_sort_and_charges_bytes() {
        check("terasort-u64-vs-std", 25, |g: &mut Gen| {
            let n = g.usize_in(0, 3000);
            let items: Vec<(u64, u32)> = (0..n)
                .map(|i| (g.usize_in(0, 50) as u64, i as u32))
                .collect();
            let ledger = CostLedger::new(4);
            let sorted = terasort_u64(items.clone(), 4, 12, |x| x.0, &ledger);
            let mut want = items;
            want.sort_by_key(|x| x.0); // std stable sort = position-tied order
            assert_eq!(sorted, want);
            assert_eq!(ledger.report(0.0).shuffle_bytes, 2 * 12 * n as u64);
        });
    }

    #[test]
    fn terasort_u64_is_worker_invariant() {
        let mut rng = crate::util::rng::Rng::new(8);
        let items: Vec<u64> = (0..20_000).map(|_| rng.next_u64() % 97).collect();
        let ledger = CostLedger::new(8);
        let one = terasort_u64(items.clone(), 1, 8, |x| *x, &ledger);
        for workers in [2usize, 5, 8] {
            assert_eq!(terasort_u64(items.clone(), workers, 8, |x| *x, &ledger), one);
        }
    }
}
