//! Distributed hash table feature store (paper §4, the RAM-heavy join).
//!
//! "The DHT caches the entire input dataset in memory across multiple
//! machines, requiring O(n) RAM but no additional on-disk storage. This
//! enables online feature lookup as we process each bucket."
//!
//! Here shards are slices of the dataset owned by virtual machines; lookups
//! count RPCs and bytes on the ledger so the join strategies can be compared
//! quantitatively (the shuffle join instead pays `shuffle_bytes`).

use super::metrics::CostLedger;
use crate::data::types::Dataset;

/// Sharded in-memory feature store over a dataset.
pub struct Dht<'a> {
    ds: &'a Dataset,
    shards: usize,
}

impl<'a> Dht<'a> {
    /// Build over `ds` with `shards` virtual owners.
    pub fn new(ds: &'a Dataset, shards: usize) -> Dht<'a> {
        Dht {
            ds,
            shards: shards.max(1),
        }
    }

    /// Which shard owns point `i`.
    #[inline]
    pub fn shard_of(&self, i: u32) -> usize {
        // Multiplicative hash so contiguous ids spread across shards.
        (crate::util::fxhash::hash_u64(i as u64) % self.shards as u64) as usize
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Approximate per-point payload size in bytes (dense + set features).
    pub fn payload_bytes(&self, i: u32) -> u64 {
        let dense = self.ds.dim() * 4;
        let set = if self.ds.sets.is_empty() {
            0
        } else {
            self.ds.set(i as usize).len() * 8
        };
        (dense + set) as u64
    }

    /// Look up the dense features of `i`, charging the ledger.
    pub fn lookup_row(&self, i: u32, ledger: &CostLedger) -> &'a [f32] {
        ledger.add_dht_lookup(self.payload_bytes(i));
        self.ds.row(i as usize)
    }

    /// Batch lookup: charges one RPC per *distinct shard* touched plus the
    /// payload bytes — modeling request coalescing in the real system.
    ///
    /// Responses carry a content checksum; when the ledger's fault plan
    /// injects corruption the batch fails verification and is re-fetched
    /// (re-charging RPCs and bytes). Lookups are reads of an immutable
    /// store, so the retried response is identical — recovery never
    /// perturbs results.
    pub fn lookup_batch(&self, ids: &[u32], ledger: &CostLedger) -> u64 {
        let plan = *ledger.faults();
        let mut shard_mask = vec![false; self.shards];
        let mut bytes = 0u64;
        // Content digest identifying this batch's response payload — the
        // corruption stream key, so the schedule is a pure function of
        // *what* was fetched, not of call order.
        let mut digest = 0u64;
        for &i in ids {
            shard_mask[self.shard_of(i)] = true;
            bytes += self.payload_bytes(i);
            digest = digest.wrapping_add(crate::util::fxhash::hash_u64(i as u64 ^ 0xD47A));
        }
        let rpcs = shard_mask.iter().filter(|&&m| m).count() as u64;
        let mut attempt = 0u32;
        loop {
            for _ in 0..rpcs {
                ledger.add_dht_lookup(0);
            }
            ledger.add_dht_lookup(bytes); // payload accounted once per fetch
            if !plan.corrupt(digest, attempt) {
                return bytes;
            }
            ledger.add_corruption_retry();
            attempt += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn shards_are_stable_and_spread() {
        let ds = synth::gaussian_mixture(100, 8, 4, 0.1, 1);
        let dht = Dht::new(&ds, 8);
        let mut counts = vec![0usize; 8];
        for i in 0..100u32 {
            assert_eq!(dht.shard_of(i), dht.shard_of(i));
            counts[dht.shard_of(i)] += 1;
        }
        assert!(counts.iter().filter(|&&c| c > 0).count() >= 6, "{counts:?}");
    }

    #[test]
    fn lookup_charges_ledger() {
        let ds = synth::gaussian_mixture(50, 8, 4, 0.1, 2);
        let dht = Dht::new(&ds, 4);
        let ledger = CostLedger::new(1);
        let row = dht.lookup_row(3, &ledger);
        assert_eq!(row.len(), 8);
        let r = ledger.report(0.0);
        assert_eq!(r.dht_lookups, 1);
        assert_eq!(r.dht_bytes, 32);
    }

    #[test]
    fn batch_lookup_coalesces() {
        let ds = synth::gaussian_mixture(50, 8, 4, 0.1, 2);
        let dht = Dht::new(&ds, 4);
        let ledger = CostLedger::new(1);
        let bytes = dht.lookup_batch(&[0, 1, 2, 3, 4, 5], &ledger);
        assert_eq!(bytes, 6 * 32);
        let r = ledger.report(0.0);
        assert!(r.dht_lookups <= 5, "too many rpcs: {}", r.dht_lookups);
    }

    #[test]
    fn corrupted_batch_is_refetched() {
        use crate::util::fault::FaultPlan;
        let ds = synth::gaussian_mixture(50, 8, 4, 0.1, 2);
        let dht = Dht::new(&ds, 4);
        let clean = CostLedger::new(1);
        let want = dht.lookup_batch(&[0, 1, 2], &clean);
        let plan = FaultPlan::parse("seed=4,corrupt=1.0,max_failures=2").unwrap();
        let ledger = CostLedger::with_faults(1, plan);
        let bytes = dht.lookup_batch(&[0, 1, 2], &ledger);
        assert_eq!(bytes, want, "retried fetch returns the same payload");
        let r = ledger.report(0.0);
        assert_eq!(r.faults.corruption_retries, 2, "corrupt=1.0 retries to the budget");
        // Each re-fetch re-charges: bytes charged = 3 fetches × payload.
        assert_eq!(r.dht_bytes, 3 * want);
    }
}
