//! Simulated AMPC cluster (paper §4).
//!
//! The paper's implementation runs on an Adaptive Massively Parallel
//! Computation fleet of ~1000 workers. Here a [`Cluster`] is a pool of
//! worker threads, each with a cost ledger, reproducing the paper's two
//! reported metrics:
//!
//! * **total running time** — the sum of per-worker busy time (the paper's
//!   "summation of running time of building edges over all machines"), and
//! * **real running time** — wall clock of the whole job.
//!
//! The feature-join strategies of §4 are implemented faithfully:
//! [`Dht`] (cache the dataset in memory across shards; per-bucket feature
//! lookups) and [`shuffle`] (TeraSort-style distributed sort to co-locate
//! features with sketches, paying disk/shuffle bytes instead of RAM).

mod cluster;
mod dht;
mod metrics;
pub mod shuffle;
pub mod terasort;

pub use cluster::Cluster;
pub use dht::Dht;
pub use metrics::{CostLedger, CostReport, FaultCounters, SnapshotStats};
