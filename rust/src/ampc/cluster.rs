//! The simulated worker fleet.

use super::metrics::{CostLedger, CostReport};
use crate::util::pool;
use std::sync::Arc;
use std::time::Instant;

/// A pool of worker "machines" sharing a [`CostLedger`].
///
/// `map_timed` is the core primitive: distribute independent tasks over the
/// workers, timing each worker's busy span and charging it to the ledger —
/// so "total running time" (Σ busy) and "real running time" (wall clock)
/// reproduce the paper's two reported quantities.
pub struct Cluster {
    workers: usize,
    ledger: Arc<CostLedger>,
}

impl Cluster {
    /// Cluster with an explicit worker count.
    pub fn new(workers: usize) -> Cluster {
        let workers = workers.max(1);
        Cluster {
            workers,
            ledger: Arc::new(CostLedger::new(workers)),
        }
    }

    /// Cluster sized to the host.
    pub fn auto() -> Cluster {
        Cluster::new(pool::default_workers())
    }

    /// Worker count.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Shared ledger.
    pub fn ledger(&self) -> &Arc<CostLedger> {
        &self.ledger
    }

    /// Run `f(task_id, &ledger)` for each task in [0, tasks), dynamically
    /// balanced over the workers; per-task busy time is charged to the
    /// executing worker. Results are returned in task order.
    pub fn map_timed<R, F>(&self, tasks: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize, &CostLedger) -> R + Sync,
    {
        let ledger = Arc::clone(&self.ledger);
        // Distribute tasks over workers; charge each task's duration to the
        // worker slot it ran on. parallel_map's cursor assigns dynamically;
        // we approximate the worker id by the thread's task order (round
        // robin on the ledger slots is fine for Σ-busy accounting).
        let counter = std::sync::atomic::AtomicUsize::new(0);
        pool::parallel_map(tasks, self.workers, |task| {
            let slot =
                counter.fetch_add(1, std::sync::atomic::Ordering::Relaxed) % self.workers;
            let t = Instant::now();
            let r = f(task, &ledger);
            ledger.add_busy(slot, t.elapsed().as_nanos() as u64);
            r
        })
    }

    /// Run a whole job (closure over this cluster) and produce its cost
    /// report with real (wall-clock) time filled in.
    pub fn run_job<R, F: FnOnce(&Cluster) -> R>(&self, f: F) -> (R, CostReport) {
        let t = Instant::now();
        let r = f(self);
        let report = self.ledger.report(t.elapsed().as_secs_f64());
        (r, report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_timed_returns_ordered_results_and_charges_time() {
        let c = Cluster::new(4);
        let out = c.map_timed(20, |task, ledger| {
            ledger.add_comparisons(1);
            // Busy-wait a tiny deterministic amount.
            let t = Instant::now();
            while t.elapsed().as_micros() < 200 {}
            task * 2
        });
        assert_eq!(out, (0..20).map(|t| t * 2).collect::<Vec<_>>());
        assert_eq!(c.ledger().comparisons(), 20);
        assert!(c.ledger().total_time() > 0.0);
    }

    #[test]
    fn run_job_reports_real_time() {
        let c = Cluster::new(2);
        let (val, report) = c.run_job(|c| {
            c.map_timed(4, |t, _| t);
            42
        });
        assert_eq!(val, 42);
        assert!(report.real_time >= 0.0);
        assert_eq!(report.workers, 2);
    }

    #[test]
    fn total_time_exceeds_real_time_under_parallelism() {
        // With 4 workers each busy ~2ms, total ≈ 8ms but real ≈ 2ms.
        let c = Cluster::new(4);
        let (_, report) = c.run_job(|c| {
            c.map_timed(4, |_, _| {
                let t = Instant::now();
                while t.elapsed().as_millis() < 5 {}
            });
        });
        assert!(
            report.total_time > report.real_time,
            "total {} !> real {}",
            report.total_time,
            report.real_time
        );
    }
}
