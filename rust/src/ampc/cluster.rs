//! The simulated worker fleet.

use super::metrics::{CostLedger, CostReport};
use crate::util::fault::{Fault, FaultPlan};
use crate::util::fxhash::FxHashMap;
use crate::util::pool;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// First retry backoff, milliseconds. Doubles per attempt up to
/// [`BACKOFF_CAP_MS`] — real backoff shape, toy constants (the fleet is
/// simulated; tests shouldn't spend seconds sleeping).
const BACKOFF_BASE_MS: u64 = 1;
/// Backoff ceiling, milliseconds.
const BACKOFF_CAP_MS: u64 = 8;
/// In-place retry budget per `map_timed` execution of a task. A task whose
/// schedule crashes it more often than this panics out to the wave level,
/// where the builder restarts the wave from its checkpoint — exercising the
/// coarse recovery path, not just the fine one.
const CALL_RETRY_BUDGET: u32 = 3;
/// A task is a straggler when it ran longer than `median × STRAGGLER_FACTOR`
/// (and longer than [`STRAGGLER_FLOOR_NANOS`], so microsecond waves don't
/// speculate on noise).
const STRAGGLER_FACTOR: u64 = 8;
/// Minimum absolute duration before a task can be called a straggler.
const STRAGGLER_FLOOR_NANOS: u64 = 25_000_000;

/// A pool of worker "machines" sharing a [`CostLedger`].
///
/// `map_timed` is the core primitive: distribute independent tasks over the
/// workers, timing each worker's busy span and charging it to the ledger —
/// so "total running time" (Σ busy) and "real running time" (wall clock)
/// reproduce the paper's two reported quantities.
///
/// # Failure model
///
/// When the ledger carries an active [`FaultPlan`] (from `STARS_FAULTS` or
/// [`Cluster::with_faults`]), each task attempt first consults the plan:
/// an injected *crash* records a failure and retries the task with capped
/// exponential backoff (never having run `f`, so no partial effects); an
/// injected *delay* stalls the attempt to manufacture a straggler. Real
/// panics out of `f` are caught and retried the same way. Failure counts
/// persist across wave restarts (keyed by `(round, task)` — the simulated
/// analogue of the AMPC controller's per-task attempt record), so a
/// schedule that crashes a task `max_failures` times converges no matter
/// how the work is re-driven. Recovery is pure re-execution of
/// deterministic tasks: results, and therefore output edges and serve
/// top-k, are bit-identical to a fault-free run.
pub struct Cluster {
    workers: usize,
    ledger: Arc<CostLedger>,
    /// Recorded failures per `(round, task)` decision point, surviving
    /// wave restarts within this cluster's lifetime.
    attempts: Mutex<FxHashMap<(u64, u64), u32>>,
}

impl Cluster {
    /// Cluster with an explicit worker count; fault schedule from
    /// `STARS_FAULTS` (inert when unset).
    pub fn new(workers: usize) -> Cluster {
        Cluster::with_faults(workers, FaultPlan::from_env())
    }

    /// Cluster with an explicit worker count and fault schedule. Tests use
    /// this instead of the env var (parallel test threads race on setenv).
    pub fn with_faults(workers: usize, faults: FaultPlan) -> Cluster {
        let workers = workers.max(1);
        Cluster {
            workers,
            ledger: Arc::new(CostLedger::with_faults(workers, faults)),
            attempts: Mutex::new(FxHashMap::default()),
        }
    }

    /// Cluster sized to the host.
    pub fn auto() -> Cluster {
        Cluster::new(pool::default_workers())
    }

    /// Worker count.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Shared ledger.
    pub fn ledger(&self) -> &Arc<CostLedger> {
        &self.ledger
    }

    /// Recorded failures at a decision point.
    fn failures(&self, key: (u64, u64)) -> u32 {
        *self.attempts.lock().unwrap().get(&key).unwrap_or(&0)
    }

    /// Record one more failure at a decision point.
    fn record_failure(&self, key: (u64, u64)) {
        *self.attempts.lock().unwrap().entry(key).or_insert(0) += 1;
    }

    /// Run one task to completion under the fault plan: consult the
    /// schedule, absorb injected crashes/delays and real panics with capped
    /// backoff, and return `f`'s (deterministic) result.
    fn run_task<R, F>(&self, plan: &FaultPlan, round: u64, task: usize, f: &F) -> R
    where
        F: Fn(usize, &CostLedger) -> R + Sync,
    {
        let ledger = &*self.ledger;
        if !plan.is_active() {
            // Hot path: no schedule, no attempt map, no unwind shim here
            // (the pool already isolates panics per task).
            return f(task, ledger);
        }
        let key = (round, task as u64);
        let mut call_crashes = 0u32;
        let mut real_panics = 0u32;
        let mut backoff_ms = BACKOFF_BASE_MS;
        loop {
            match plan.decide(round, task as u64, self.failures(key)) {
                Fault::Crash => {
                    self.record_failure(key);
                    ledger.add_injected_crash();
                    call_crashes += 1;
                    if call_crashes >= CALL_RETRY_BUDGET {
                        // Escalate to the wave level: the builder restarts
                        // the wave from its checkpoint; our failure record
                        // persists, so the schedule eventually relents.
                        panic!(
                            "injected crash: round {round} task {task} exhausted \
                             its in-place retry budget"
                        );
                    }
                    ledger.add_task_retry();
                    std::thread::sleep(Duration::from_millis(backoff_ms));
                    backoff_ms = (backoff_ms * 2).min(BACKOFF_CAP_MS);
                    continue;
                }
                Fault::Delay(ms) => {
                    ledger.add_injected_delay();
                    std::thread::sleep(Duration::from_millis(ms));
                }
                Fault::None => {}
            }
            match catch_unwind(AssertUnwindSafe(|| f(task, ledger))) {
                Ok(r) => return r,
                Err(payload) => {
                    self.record_failure(key);
                    real_panics += 1;
                    if real_panics >= CALL_RETRY_BUDGET {
                        resume_unwind(payload);
                    }
                    ledger.add_task_retry();
                    std::thread::sleep(Duration::from_millis(backoff_ms));
                    backoff_ms = (backoff_ms * 2).min(BACKOFF_CAP_MS);
                }
            }
        }
    }

    /// Run `f(task_id, &ledger)` for each task in [0, tasks), dynamically
    /// balanced over the workers; per-task busy time is charged to the
    /// executing worker. Results are returned in task order. Fault-schedule
    /// decisions use round 0 (callers with a real round structure use
    /// [`Cluster::map_timed_round`]).
    pub fn map_timed<R, F>(&self, tasks: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize, &CostLedger) -> R + Sync,
    {
        self.map_timed_round(0, tasks, f)
    }

    /// [`Cluster::map_timed`] with an explicit round label: the fault
    /// schedule keys decisions on `(round, task)`, so a builder driving
    /// repetition `r` as round `r` gets per-repetition schedules that stay
    /// stable when a wave is restarted.
    pub fn map_timed_round<R, F>(&self, round: u64, tasks: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize, &CostLedger) -> R + Sync,
    {
        let ledger = Arc::clone(&self.ledger);
        let plan = *self.ledger.faults();
        // Wave span: wall time of the fan-out on the coordinator; its busy
        // aggregates every task's duration (Σ task time), mirroring the
        // ledger's Σ-busy accounting. Observation only — never consulted.
        let wave_span = self.ledger.phases().enter("wave");
        // Distribute tasks over workers; charge each task's duration to the
        // worker slot it ran on. parallel_map's cursor assigns dynamically;
        // we approximate the worker id by the thread's task order (round
        // robin on the ledger slots is fine for Σ-busy accounting).
        let counter = std::sync::atomic::AtomicUsize::new(0);
        let durations: Vec<AtomicU64> = (0..tasks).map(|_| AtomicU64::new(0)).collect();
        let mut out = pool::parallel_map(tasks, self.workers, |task| {
            let slot =
                counter.fetch_add(1, std::sync::atomic::Ordering::Relaxed) % self.workers;
            let t = Instant::now();
            let r = self.run_task(&plan, round, task, &f);
            let nanos = t.elapsed().as_nanos() as u64;
            durations[task].store(nanos, Ordering::Relaxed);
            ledger.add_busy(slot, nanos);
            wave_span.add_busy(nanos);
            r
        });
        // Straggler pass: speculatively re-execute tasks that ran far past
        // the wave median (injected delays manufacture these). `f` is
        // deterministic, so the re-executed result replaces the original
        // bit-for-bit; gated on an active plan so fault-free builds never
        // pay for (or double-charge) a speculative run.
        if plan.is_active() && tasks >= 2 {
            let mut sorted: Vec<u64> = durations.iter().map(|d| d.load(Ordering::Relaxed)).collect();
            sorted.sort_unstable();
            let median = sorted[tasks / 2];
            let threshold = (median.saturating_mul(STRAGGLER_FACTOR)).max(STRAGGLER_FLOOR_NANOS);
            for (task, d) in durations.iter().enumerate() {
                if d.load(Ordering::Relaxed) > threshold {
                    ledger.add_straggler();
                    out[task] = f(task, &*ledger);
                }
            }
        }
        out
    }

    /// Run a whole job (closure over this cluster) and produce its cost
    /// report with real (wall-clock) time filled in.
    pub fn run_job<R, F: FnOnce(&Cluster) -> R>(&self, f: F) -> (R, CostReport) {
        let t = Instant::now();
        let r = f(self);
        let report = self.ledger.report(t.elapsed().as_secs_f64());
        (r, report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_timed_returns_ordered_results_and_charges_time() {
        let c = Cluster::with_faults(4, FaultPlan::none());
        let out = c.map_timed(20, |task, ledger| {
            ledger.add_comparisons(1);
            // Busy-wait a tiny deterministic amount.
            let t = Instant::now();
            while t.elapsed().as_micros() < 200 {}
            task * 2
        });
        assert_eq!(out, (0..20).map(|t| t * 2).collect::<Vec<_>>());
        assert_eq!(c.ledger().comparisons(), 20);
        assert!(c.ledger().total_time() > 0.0);
        assert!(!c.ledger().fault_counters().any(), "clean run, zero counters");
    }

    #[test]
    fn run_job_reports_real_time() {
        let c = Cluster::with_faults(2, FaultPlan::none());
        let (val, report) = c.run_job(|c| {
            c.map_timed(4, |t, _| t);
            42
        });
        assert_eq!(val, 42);
        assert!(report.real_time >= 0.0);
        assert_eq!(report.workers, 2);
    }

    #[test]
    fn total_time_exceeds_real_time_under_parallelism() {
        // With 4 workers each busy ~2ms, total ≈ 8ms but real ≈ 2ms.
        let c = Cluster::with_faults(4, FaultPlan::none());
        let (_, report) = c.run_job(|c| {
            c.map_timed(4, |_, _| {
                let t = Instant::now();
                while t.elapsed().as_millis() < 5 {}
            });
        });
        assert!(
            report.total_time > report.real_time,
            "total {} !> real {}",
            report.total_time,
            report.real_time
        );
    }

    #[test]
    fn injected_crashes_retry_to_identical_results() {
        let plan = FaultPlan::parse("seed=5,crash=0.9,max_failures=2").unwrap();
        for workers in [1usize, 4] {
            let c = Cluster::with_faults(workers, plan);
            let out = c.map_timed(12, |task, _| task * 3);
            assert_eq!(out, (0..12).map(|t| t * 3).collect::<Vec<_>>());
            let counters = c.ledger().fault_counters();
            assert!(counters.injected_crashes > 0, "schedule should fire");
            assert!(counters.task_retries > 0);
        }
    }

    #[test]
    fn injected_delays_trigger_straggler_reexecution() {
        // One wave, every task fast except the delayed ones (~60ms vs
        // microseconds): the straggler pass must fire and results stay
        // identical.
        let plan = FaultPlan::parse("seed=6,delay=0.75:60").unwrap();
        let c = Cluster::with_faults(4, plan);
        let out = c.map_timed(8, |task, _| task + 1);
        assert_eq!(out, (1..=8).collect::<Vec<_>>());
        let counters = c.ledger().fault_counters();
        assert!(counters.injected_delays > 0, "schedule should fire");
        assert!(counters.stragglers > 0, "delayed tasks should be re-run");
    }

    #[test]
    fn real_panic_is_retried_then_surfaced() {
        use std::sync::atomic::AtomicUsize;
        // An always-panicking task under an active plan: retried
        // CALL_RETRY_BUDGET times in place, then the panic surfaces.
        let plan = FaultPlan::parse("seed=1,delay=0.0:0,corrupt=0.01").unwrap();
        let c = Cluster::with_faults(1, plan);
        let calls = AtomicUsize::new(0);
        let r = catch_unwind(AssertUnwindSafe(|| {
            c.map_timed(1, |_, _| {
                calls.fetch_add(1, Ordering::Relaxed);
                panic!("boom");
            })
        }));
        assert!(r.is_err());
        assert_eq!(calls.load(Ordering::Relaxed), CALL_RETRY_BUDGET as usize);
        assert_eq!(c.ledger().fault_counters().task_retries, u64::from(CALL_RETRY_BUDGET) - 1);
    }

    #[test]
    fn failure_record_survives_wave_restart() {
        // crash=1.0 with max_failures above the in-place budget: the first
        // map_timed panics out (budget exhausted); re-driving the same
        // round converges because recorded failures persist on the cluster.
        let plan = FaultPlan::parse("seed=2,crash=1.0,max_failures=5").unwrap();
        let c = Cluster::with_faults(2, plan);
        let mut restarts = 0;
        let out = loop {
            match catch_unwind(AssertUnwindSafe(|| c.map_timed_round(7, 3, |t, _| t * 10))) {
                Ok(r) => break r,
                Err(_) => {
                    restarts += 1;
                    assert!(restarts < 10, "must converge");
                }
            }
        };
        assert_eq!(out, vec![0, 10, 20]);
        assert!(restarts > 0, "budget 5 > in-place budget must escalate");
    }
}
