//! Bench harness (criterion is not in the vendor set).
//!
//! Each `rust/benches/*.rs` is a `harness = false` binary that uses this
//! module to time workloads and print paper-style tables. Reports median and
//! spread over repeated runs, plus throughput when a unit count is given.

use std::time::Instant;

/// Timing statistics over repeated runs of a workload.
#[derive(Clone, Debug)]
pub struct Stats {
    /// Run durations, seconds, sorted ascending.
    pub samples: Vec<f64>,
}

impl Stats {
    /// Median seconds.
    pub fn median(&self) -> f64 {
        let n = self.samples.len();
        if n == 0 {
            return 0.0;
        }
        if n % 2 == 1 {
            self.samples[n / 2]
        } else {
            0.5 * (self.samples[n / 2 - 1] + self.samples[n / 2])
        }
    }

    /// Minimum seconds.
    pub fn min(&self) -> f64 {
        self.samples.first().copied().unwrap_or(0.0)
    }

    /// Maximum seconds.
    pub fn max(&self) -> f64 {
        self.samples.last().copied().unwrap_or(0.0)
    }
}

/// Time `f` for `runs` runs after `warmup` unmeasured runs.
pub fn time_runs<F: FnMut()>(warmup: usize, runs: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(runs);
    for _ in 0..runs {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Stats { samples }
}

/// Time one run of `f`, returning (seconds, result).
pub fn time_once<R, F: FnOnce() -> R>(f: F) -> (f64, R) {
    let t = Instant::now();
    let r = f();
    (t.elapsed().as_secs_f64(), r)
}

/// Pretty-print seconds with an adaptive unit.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}s", s)
    }
}

/// Pretty-print a count with thousands separators.
pub fn fmt_count(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::new();
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

/// Simple fixed-width table printer for bench/experiment outputs.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Table with the given column headers.
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match header count).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Render to a string with aligned columns.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::new();
            for (c, cell) in cells.iter().enumerate() {
                if c > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:>width$}", cell, width = widths[c]));
            }
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_median() {
        let s = Stats {
            samples: vec![1.0, 2.0, 3.0],
        };
        assert_eq!(s.median(), 2.0);
        let s = Stats {
            samples: vec![1.0, 2.0, 3.0, 4.0],
        };
        assert_eq!(s.median(), 2.5);
    }

    #[test]
    fn time_runs_counts() {
        let mut calls = 0;
        let stats = time_runs(2, 5, || calls += 1);
        assert_eq!(calls, 7);
        assert_eq!(stats.samples.len(), 5);
        assert!(stats.min() <= stats.median() && stats.median() <= stats.max());
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_count(1234567), "1,234,567");
        assert_eq!(fmt_count(7), "7");
        assert!(fmt_secs(0.5).ends_with("ms"));
        assert!(fmt_secs(2.0).ends_with('s'));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["algo", "comparisons"]);
        t.row(vec!["stars".into(), "123".into()]);
        t.row(vec!["allpair".into(), "4567890".into()]);
        let r = t.render();
        assert!(r.contains("stars"));
        assert!(r.lines().count() == 4);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_bad_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x".into()]);
    }
}
