//! `stars::obs` — the structured observability layer: phase spans, lock-free
//! histograms, a metrics registry, and NDJSON/Prometheus export.
//!
//! Everything in this module is *observation only*. The contract (the same
//! bit-identity contract the kernels and the fault layer honor): tracing on
//! or off, sampled or not, must never alter edges, top-k results, or any
//! `CostReport` counter — it may only **add** to reports. The layer is
//! fully off the hot path: with `STARS_TRACE` unset, every emission site
//! costs one relaxed atomic load, and metric recording is a handful of
//! relaxed atomic adds (both measured by the microbench overhead probe and
//! reported in `BENCH_scoring.json`).
//!
//! The four pieces:
//!
//! * [`span`] — hierarchical phase spans with RAII guards, collected
//!   per-job on `CostLedger` (build pipeline) and reported as
//!   `CostReport::phases`;
//! * [`hist`] — log-bucketed (power-of-2, 16 sub-buckets) histograms with
//!   deterministic, count-conserving merge;
//! * [`registry`] — the process-global named-metric registry plus the
//!   Prometheus text renderer and the atomic snapshot writer behind
//!   `stars serve --metrics-out`;
//! * [`sink`] — the `STARS_TRACE=<path>` NDJSON event sink with
//!   deterministic `STARS_TRACE_SAMPLE=1/N` sampling.
//!
//! Schemas are documented in EXPERIMENTS.md §Observability; the span
//! taxonomy and overhead budget in ARCHITECTURE.md "Observability".

pub mod hist;
pub mod registry;
pub mod sink;
pub mod span;

pub use hist::{
    bucket_ceil, bucket_floor, bucket_index, Histogram, HistSnapshot, NUM_BUCKETS, SUB_BUCKETS,
};
pub use registry::{registry, write_snapshot, Counter, Gauge, HistHandle, MetricsExporter, Registry};
pub use sink::{
    emit, emit_lazy, emit_log, enabled as trace_enabled, reset_to_env, sample_every, set_trace,
};
pub use span::{PhaseGuard, PhaseReport, PhaseStat, Phases};
