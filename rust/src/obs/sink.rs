//! `STARS_TRACE` NDJSON event sink with deterministic sampling.
//!
//! When `STARS_TRACE=<path>` is set (read once, at first use), every
//! emitted event becomes one JSON object per line (NDJSON) appended to
//! that file via `util::json` — so every line is guaranteed to parse back
//! with `util::json::parse` (gated in `scripts/ci.sh`). The common event
//! schema is
//!
//! ```json
//! {"kind": "span|query|compaction|log|...", "seq": 17, "ts_s": 0.132, ...}
//! ```
//!
//! plus kind-specific fields (see EXPERIMENTS.md §Observability for the
//! full catalogue). `seq` is a process-global event index; `ts_s` is
//! seconds since the logging epoch (`util::logging::elapsed`).
//!
//! `STARS_TRACE_SAMPLE=1/N` (or plain `N`) keeps every N-th event,
//! decided deterministically on the event index — no RNG, so a traced run
//! samples the same event *indices* every time. Sampling and tracing are
//! observation-only: nothing here can change edges, top-k, or any
//! `CostReport` counter (the bit-identity contract; asserted by the
//! tracing-parity test in `tests/obs.rs`).
//!
//! With tracing off the entire layer costs one relaxed atomic load per
//! call site (measured by the microbench overhead probe).

use crate::util::json::Json;
use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, Once, OnceLock};

static ENABLED: AtomicBool = AtomicBool::new(false);
static SAMPLE_EVERY: AtomicU64 = AtomicU64::new(1);
static SEQ: AtomicU64 = AtomicU64::new(0);
static ENV_INIT: Once = Once::new();
static SINK: OnceLock<Mutex<Option<BufWriter<File>>>> = OnceLock::new();

fn sink_cell() -> &'static Mutex<Option<BufWriter<File>>> {
    SINK.get_or_init(|| Mutex::new(None))
}

/// Parse `STARS_TRACE_SAMPLE`: `1/N` or plain `N`; 0/garbage → 1.
fn parse_sample(s: &str) -> u64 {
    let n = match s.split_once('/') {
        Some((_, denom)) => denom.trim().parse::<u64>().unwrap_or(1),
        None => s.trim().parse::<u64>().unwrap_or(1),
    };
    n.max(1)
}

fn init_from_env() {
    ENV_INIT.call_once(|| {
        if let Ok(path) = std::env::var("STARS_TRACE") {
            if !path.is_empty() {
                let every = std::env::var("STARS_TRACE_SAMPLE")
                    .map(|s| parse_sample(&s))
                    .unwrap_or(1);
                let _ = install(Path::new(&path), every);
            }
        }
    });
}

fn install(path: &Path, sample_every: u64) -> std::io::Result<()> {
    let file = OpenOptions::new().create(true).append(true).open(path)?;
    *sink_cell().lock().unwrap() = Some(BufWriter::new(file));
    SAMPLE_EVERY.store(sample_every.max(1), Ordering::Relaxed);
    ENABLED.store(true, Ordering::Relaxed);
    Ok(())
}

/// Whether the trace sink is active. One relaxed load after the first
/// call (which consumes `STARS_TRACE`/`STARS_TRACE_SAMPLE`).
#[inline]
pub fn enabled() -> bool {
    init_from_env();
    ENABLED.load(Ordering::Relaxed)
}

/// Programmatically (re-)install the sink: `Some(path)` appends NDJSON
/// events to `path` keeping every `sample_every`-th event; `None`
/// disables tracing. Overrides the environment (tests use this; call
/// [`reset_to_env`] to hand control back).
pub fn set_trace(path: Option<&Path>, sample_every: u64) -> std::io::Result<()> {
    init_from_env();
    match path {
        Some(p) => install(p, sample_every),
        None => {
            ENABLED.store(false, Ordering::Relaxed);
            *sink_cell().lock().unwrap() = None;
            Ok(())
        }
    }
}

/// Restore the sink to whatever `STARS_TRACE`/`STARS_TRACE_SAMPLE`
/// prescribe right now (appending), or disable it if unset.
pub fn reset_to_env() {
    init_from_env();
    match std::env::var("STARS_TRACE") {
        Ok(path) if !path.is_empty() => {
            let every = std::env::var("STARS_TRACE_SAMPLE")
                .map(|s| parse_sample(&s))
                .unwrap_or(1);
            let _ = install(Path::new(&path), every);
        }
        _ => {
            ENABLED.store(false, Ordering::Relaxed);
            *sink_cell().lock().unwrap() = None;
        }
    }
}

/// The active keep-every-N sampling divisor.
pub fn sample_every() -> u64 {
    init_from_env();
    SAMPLE_EVERY.load(Ordering::Relaxed).max(1)
}

/// Emit one event, building its fields lazily only if the sink is active
/// *and* the event index survives sampling. `kind`, `seq` and `ts_s` are
/// added automatically.
pub fn emit_lazy<F>(kind: &str, fields: F)
where
    F: FnOnce() -> Vec<(&'static str, Json)>,
{
    if !enabled() {
        return;
    }
    let seq = SEQ.fetch_add(1, Ordering::Relaxed);
    let every = SAMPLE_EVERY.load(Ordering::Relaxed).max(1);
    if seq % every != 0 {
        return;
    }
    let mut pairs = vec![
        ("kind", Json::from(kind)),
        ("seq", Json::from(seq)),
        ("ts_s", Json::from(crate::util::logging::elapsed())),
    ];
    pairs.extend(fields());
    let line = Json::obj(pairs).to_string();
    let mut guard = sink_cell().lock().unwrap();
    if let Some(w) = guard.as_mut() {
        let _ = writeln!(w, "{line}");
        let _ = w.flush();
    }
}

/// Emit one event with eagerly built fields.
pub fn emit(kind: &str, fields: Vec<(&'static str, Json)>) {
    emit_lazy(kind, move || fields);
}

/// Route a log line into the sink (called by `util::logging::log` for
/// every line at or above the active level).
pub fn emit_log(level: &'static str, msg: &str) {
    emit_lazy("log", || {
        vec![("level", Json::from(level)), ("msg", Json::from(msg))]
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_spec_parses() {
        assert_eq!(parse_sample("1/8"), 8);
        assert_eq!(parse_sample("16"), 16);
        assert_eq!(parse_sample("1/0"), 1);
        assert_eq!(parse_sample("junk"), 1);
        assert_eq!(parse_sample(" 1/4 "), 4);
    }

    #[test]
    fn disabled_sink_is_inert() {
        // Whatever the env says, an explicit disable must make emission a
        // no-op (and must not panic).
        set_trace(None, 1).unwrap();
        assert!(!enabled());
        emit("test", vec![("x", Json::from(1u64))]);
        reset_to_env();
        assert!(sample_every() >= 1);
    }
}
