//! Process-global metrics registry and Prometheus-text exposition.
//!
//! Named counters, gauges and [`Histogram`]s, registered once (a mutex
//! protects the name table) and recorded lock-free thereafter (handles
//! are `Arc`s over atomics). The registry renders to the Prometheus text
//! exposition format — counters and gauges as single samples, histograms
//! in summary style with `quantile` labels plus `_sum`/`_count` — and
//! [`write_snapshot`] rewrites a scrape file *atomically* (write to a
//! `.tmp` sibling, then rename), so a scraper never reads a torn file.
//!
//! `stars serve --metrics-out <path> --metrics-every <s>` runs a
//! [`MetricsExporter`] ticker thread over this registry; the serve stack
//! records query latency, queue depth, rescore width and compaction time
//! here (see EXPERIMENTS.md §Observability for the metric catalogue).

use crate::obs::hist::{HistSnapshot, Histogram};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Duration;

/// A monotonically increasing counter handle (cheap to clone).
#[derive(Clone, Debug)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    /// Add `n`.
    #[inline]
    pub fn inc(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A last-value-wins gauge handle (cheap to clone).
#[derive(Clone, Debug)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    /// Set the current value.
    #[inline]
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A histogram handle (cheap to clone; see [`Histogram`]).
#[derive(Clone, Debug)]
pub struct HistHandle(Arc<Histogram>);

impl HistHandle {
    /// Record one value.
    #[inline]
    pub fn record(&self, v: u64) {
        self.0.record(v);
    }

    /// Plain-data snapshot.
    pub fn snapshot(&self) -> HistSnapshot {
        self.0.snapshot()
    }
}

#[derive(Default)]
struct Tables {
    counters: BTreeMap<String, Arc<AtomicU64>>,
    gauges: BTreeMap<String, Arc<AtomicU64>>,
    hists: BTreeMap<String, Arc<Histogram>>,
}

/// Named-metric registry; see the module docs. Use [`registry`] for the
/// process-global instance.
#[derive(Default)]
pub struct Registry {
    tables: Mutex<Tables>,
}

impl Registry {
    /// Fresh empty registry (tests; production code uses [`registry`]).
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Get or create a counter. Metric names should match Prometheus
    /// conventions (`[a-zA-Z_][a-zA-Z0-9_]*`).
    pub fn counter(&self, name: &str) -> Counter {
        let mut t = self.tables.lock().unwrap();
        Counter(t.counters.entry(name.to_string()).or_default().clone())
    }

    /// Get or create a gauge.
    pub fn gauge(&self, name: &str) -> Gauge {
        let mut t = self.tables.lock().unwrap();
        Gauge(t.gauges.entry(name.to_string()).or_default().clone())
    }

    /// Get or create a histogram.
    pub fn histogram(&self, name: &str) -> HistHandle {
        let mut t = self.tables.lock().unwrap();
        HistHandle(t.hists.entry(name.to_string()).or_default().clone())
    }

    /// Render the whole registry in the Prometheus text exposition
    /// format. Deterministic order (names ascend); histograms render as
    /// summaries with `quantile` labels plus `_sum`/`_count`.
    pub fn render_prometheus(&self) -> String {
        let t = self.tables.lock().unwrap();
        let mut out = String::new();
        for (name, v) in &t.counters {
            out.push_str(&format!("# TYPE {name} counter\n"));
            out.push_str(&format!("{name} {}\n", v.load(Ordering::Relaxed)));
        }
        for (name, v) in &t.gauges {
            out.push_str(&format!("# TYPE {name} gauge\n"));
            out.push_str(&format!("{name} {}\n", v.load(Ordering::Relaxed)));
        }
        for (name, h) in &t.hists {
            let s = h.snapshot();
            out.push_str(&format!("# TYPE {name} summary\n"));
            for (label, q) in [("0.5", 0.5), ("0.9", 0.9), ("0.99", 0.99), ("0.999", 0.999)] {
                out.push_str(&format!("{name}{{quantile=\"{label}\"}} {}\n", s.quantile(q)));
            }
            out.push_str(&format!("{name}_sum {}\n", s.sum));
            out.push_str(&format!("{name}_count {}\n", s.count));
        }
        out
    }

    /// JSON snapshot of every metric (histograms via
    /// [`HistSnapshot::to_json`]).
    pub fn snapshot_json(&self) -> Json {
        let t = self.tables.lock().unwrap();
        let counters: Vec<(&str, Json)> = t
            .counters
            .iter()
            .map(|(k, v)| (k.as_str(), Json::from(v.load(Ordering::Relaxed))))
            .collect();
        let gauges: Vec<(&str, Json)> = t
            .gauges
            .iter()
            .map(|(k, v)| (k.as_str(), Json::from(v.load(Ordering::Relaxed))))
            .collect();
        let hists: Vec<(&str, Json)> =
            t.hists.iter().map(|(k, h)| (k.as_str(), h.snapshot().to_json())).collect();
        Json::obj(vec![
            ("counters", Json::obj(counters)),
            ("gauges", Json::obj(gauges)),
            ("histograms", Json::obj(hists)),
        ])
    }
}

/// The process-global registry.
pub fn registry() -> &'static Registry {
    static REGISTRY: OnceLock<Registry> = OnceLock::new();
    REGISTRY.get_or_init(Registry::new)
}

/// Atomically rewrite `path` with the global registry's Prometheus text
/// snapshot (write a `.tmp` sibling, then rename over).
pub fn write_snapshot(path: &Path) -> std::io::Result<()> {
    let text = registry().render_prometheus();
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, text)?;
    std::fs::rename(&tmp, path)
}

struct ExporterShared {
    stop: Mutex<bool>,
    cv: Condvar,
}

/// Background ticker that atomically rewrites a metrics snapshot every
/// interval (the `stars serve --metrics-out/--metrics-every` path).
/// Dropping it writes one final snapshot and joins the thread.
pub struct MetricsExporter {
    shared: Arc<ExporterShared>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl MetricsExporter {
    /// Start exporting to `path` every `every` (clamped to ≥ 10 ms).
    pub fn start(path: PathBuf, every: Duration) -> MetricsExporter {
        let every = every.max(Duration::from_millis(10));
        let shared = Arc::new(ExporterShared { stop: Mutex::new(false), cv: Condvar::new() });
        let shared2 = shared.clone();
        let handle = std::thread::Builder::new()
            .name("stars-metrics".into())
            .spawn(move || loop {
                let _ = write_snapshot(&path);
                let stopped = shared2.stop.lock().unwrap();
                let (stopped, _) = shared2.cv.wait_timeout(stopped, every).unwrap();
                if *stopped {
                    let _ = write_snapshot(&path);
                    break;
                }
            })
            .expect("spawn metrics exporter");
        MetricsExporter { shared, handle: Some(handle) }
    }
}

impl Drop for MetricsExporter {
    fn drop(&mut self) {
        *self.shared.stop.lock().unwrap() = true;
        self.shared.cv.notify_all();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handles_record_and_render() {
        let r = Registry::new();
        let c = r.counter("stars_test_total");
        c.inc(3);
        c.inc(2);
        assert_eq!(c.get(), 5);
        let g = r.gauge("stars_test_depth");
        g.set(7);
        assert_eq!(g.get(), 7);
        let h = r.histogram("stars_test_latency_us");
        for v in [10u64, 20, 30] {
            h.record(v);
        }
        let text = r.render_prometheus();
        assert!(text.contains("# TYPE stars_test_total counter"));
        assert!(text.contains("stars_test_total 5"));
        assert!(text.contains("stars_test_depth 7"));
        assert!(text.contains("stars_test_latency_us{quantile=\"0.5\"} 20"));
        assert!(text.contains("stars_test_latency_us_count 3"));
        assert!(text.contains("stars_test_latency_us_sum 60"));
    }

    #[test]
    fn same_name_shares_storage() {
        let r = Registry::new();
        r.counter("shared").inc(1);
        r.counter("shared").inc(1);
        assert_eq!(r.counter("shared").get(), 2);
    }

    #[test]
    fn snapshot_json_parses() {
        let r = Registry::new();
        r.counter("a_total").inc(1);
        r.histogram("b_us").record(5);
        let j = r.snapshot_json().to_string();
        let v = crate::util::json::parse(&j).unwrap();
        let counter = v.get("counters").unwrap().get("a_total").unwrap();
        assert_eq!(counter.as_usize().unwrap(), 1);
        let hist = v.get("histograms").unwrap().get("b_us").unwrap();
        assert_eq!(hist.get("count").unwrap().as_usize().unwrap(), 1);
    }

    #[test]
    fn snapshot_file_is_atomic_rewrite() {
        let dir = std::env::temp_dir().join(format!("stars_obs_reg_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("metrics.prom");
        registry().counter("stars_reg_file_test_total").inc(1);
        write_snapshot(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("stars_reg_file_test_total"));
        assert!(!path.with_extension("tmp").exists(), "tmp file must be renamed away");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
