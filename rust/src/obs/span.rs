//! Hierarchical phase spans with RAII guards and a lock-free collector.
//!
//! A [`Phases`] is a fixed-capacity table of phase slots attached to a job
//! (the build pipeline hangs one off its `CostLedger`; the serve stack has
//! a process-global one). [`Phases::enter`] pushes a span onto a
//! per-thread stack and returns a [`PhaseGuard`]; dropping the guard adds
//! the span's inclusive nanoseconds to its slot with relaxed atomic adds —
//! no locks anywhere on the record path (slot *creation* goes through a
//! `OnceLock` claim, once per distinct phase path per process).
//!
//! Nesting is per-thread: a span entered while another span of the *same*
//! `Phases` instance is active on the same thread becomes its child, and
//! the slot identity is `(parent slot, name)` — so `"build" > "rep" >
//! "sketch"` and a bare `"sketch"` entered elsewhere are different phases.
//! Pool workers start with an empty stack, so spans recorded inside
//! parallel tasks root their own subtree (the builder names them
//! accordingly, e.g. `build/rep`); guards are truncation-safe — dropping
//! an outer guard pops any leaked inner entries, so the stack can never
//! cross or orphan spans (asserted by `tests/obs.rs` under every worker
//! count).
//!
//! Each slot tracks `{count, nanos, busy_nanos, bytes}`: `nanos` is the
//! inclusive span time summed over instances (wall for coordinator-side
//! phases, Σ task time for per-task phases), `busy_nanos` is data-parallel
//! worker time explicitly attributed via [`PhaseGuard::add_busy`] (the
//! in-repetition drivers feed it from their pool busy callbacks), `bytes`
//! is whatever the caller attributes via [`PhaseGuard::add_bytes`].
//!
//! Tracing never changes results: guards only read clocks and bump
//! counters, and the whole layer is additive to `CostReport` (the
//! bit-identity contract — see ARCHITECTURE.md "Observability").

use crate::util::json::Json;
use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Phase slots per [`Phases`] instance (power of two; open addressing).
const SLOTS: usize = 128;
/// Parent marker for root spans.
const ROOT: u32 = u32::MAX;

static NEXT_ID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    /// Active span stack: `(Phases instance id, slot index)` per entry.
    static SPAN_STACK: RefCell<Vec<(u64, u32)>> = RefCell::new(Vec::new());
}

#[derive(Debug)]
struct Slot {
    /// `(parent slot index or ROOT, segment name)`; unset = free.
    meta: OnceLock<(u32, &'static str)>,
    nanos: AtomicU64,
    busy_nanos: AtomicU64,
    count: AtomicU64,
    bytes: AtomicU64,
}

/// A job-scoped phase-span collector. Cheap to share (`&Phases` records
/// concurrently from any thread); see the module docs for the model.
#[derive(Debug)]
pub struct Phases {
    id: u64,
    slots: Vec<Slot>,
    dropped: AtomicU64,
}

impl Default for Phases {
    fn default() -> Phases {
        Phases::new()
    }
}

impl Phases {
    /// Empty collector with a fresh instance identity.
    pub fn new() -> Phases {
        Phases {
            id: NEXT_ID.fetch_add(1, Ordering::Relaxed),
            slots: (0..SLOTS)
                .map(|_| Slot {
                    meta: OnceLock::new(),
                    nanos: AtomicU64::new(0),
                    busy_nanos: AtomicU64::new(0),
                    count: AtomicU64::new(0),
                    bytes: AtomicU64::new(0),
                })
                .collect(),
            dropped: AtomicU64::new(0),
        }
    }

    fn slot_for(&self, parent: u32, name: &'static str) -> Option<u32> {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325 ^ parent as u64;
        for b in name.bytes() {
            h = (h ^ b as u64).wrapping_mul(0x0100_0000_01b3);
        }
        for i in 0..SLOTS {
            let idx = (h as usize + i) & (SLOTS - 1);
            let slot = &self.slots[idx];
            match slot.meta.get() {
                Some(&(p, n)) if p == parent && n == name => return Some(idx as u32),
                Some(_) => continue,
                None => {
                    if slot.meta.set((parent, name)).is_ok() {
                        return Some(idx as u32);
                    }
                    // Lost the claim race — re-check what won.
                    if let Some(&(p, n)) = slot.meta.get() {
                        if p == parent && n == name {
                            return Some(idx as u32);
                        }
                    }
                }
            }
        }
        self.dropped.fetch_add(1, Ordering::Relaxed);
        None
    }

    /// Enter a span named `name`, child of the innermost active span of
    /// this instance on the current thread (root otherwise). The returned
    /// guard records on drop. If the slot table is full the span is
    /// counted as dropped and the guard records nothing.
    pub fn enter(&self, name: &'static str) -> PhaseGuard<'_> {
        self.enter_impl(name, None)
    }

    /// Enter a span anchored at the root regardless of what is active on
    /// the current thread. Per-task phases use this (e.g. the builder's
    /// `build/rep`) so their path is identical whether the task runs on a
    /// pool worker or is re-executed on the coordinator (straggler pass);
    /// child spans entered on the same thread still nest under it.
    pub fn enter_root(&self, name: &'static str) -> PhaseGuard<'_> {
        self.enter_impl(name, Some(ROOT))
    }

    fn enter_impl(&self, name: &'static str, forced_parent: Option<u32>) -> PhaseGuard<'_> {
        let (prior_len, slot) = SPAN_STACK.with(|s| {
            let mut s = s.borrow_mut();
            let parent = forced_parent.unwrap_or_else(|| {
                s.iter()
                    .rev()
                    .find(|&&(id, _)| id == self.id)
                    .map(|&(_, slot)| slot)
                    .unwrap_or(ROOT)
            });
            let slot = self.slot_for(parent, name);
            let len = s.len();
            if let Some(idx) = slot {
                s.push((self.id, idx));
            }
            (len, slot)
        });
        PhaseGuard { phases: self, slot, prior_len, start: Instant::now() }
    }

    /// Full `/`-joined path of a slot.
    fn path_of(&self, idx: u32) -> String {
        let mut segs: Vec<&'static str> = Vec::new();
        let mut cur = idx;
        while cur != ROOT {
            match self.slots[cur as usize].meta.get() {
                Some(&(parent, name)) => {
                    segs.push(name);
                    cur = parent;
                }
                None => break,
            }
        }
        segs.reverse();
        segs.join("/")
    }

    /// Spans that could not be recorded (slot table full).
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Snapshot of every recorded phase, sorted by path.
    pub fn report(&self) -> PhaseReport {
        let mut phases: Vec<PhaseStat> = Vec::new();
        for (i, slot) in self.slots.iter().enumerate() {
            if slot.meta.get().is_none() {
                continue;
            }
            let count = slot.count.load(Ordering::Relaxed);
            if count == 0 {
                continue;
            }
            phases.push(PhaseStat {
                path: self.path_of(i as u32),
                count,
                secs: slot.nanos.load(Ordering::Relaxed) as f64 / 1e9,
                busy_secs: slot.busy_nanos.load(Ordering::Relaxed) as f64 / 1e9,
                bytes: slot.bytes.load(Ordering::Relaxed),
            });
        }
        phases.sort_by(|a, b| a.path.cmp(&b.path));
        PhaseReport { phases, dropped: self.dropped() }
    }
}

/// RAII span guard returned by [`Phases::enter`].
#[derive(Debug)]
pub struct PhaseGuard<'p> {
    phases: &'p Phases,
    slot: Option<u32>,
    prior_len: usize,
    start: Instant,
}

impl PhaseGuard<'_> {
    /// Attribute data-parallel worker-busy nanoseconds to this phase
    /// (callable concurrently — pool busy callbacks feed this).
    pub fn add_busy(&self, nanos: u64) {
        if let Some(idx) = self.slot {
            self.phases.slots[idx as usize].busy_nanos.fetch_add(nanos, Ordering::Relaxed);
        }
    }

    /// Attribute processed bytes to this phase.
    pub fn add_bytes(&self, bytes: u64) {
        if let Some(idx) = self.slot {
            self.phases.slots[idx as usize].bytes.fetch_add(bytes, Ordering::Relaxed);
        }
    }
}

impl Drop for PhaseGuard<'_> {
    fn drop(&mut self) {
        let nanos = self.start.elapsed().as_nanos() as u64;
        SPAN_STACK.with(|s| s.borrow_mut().truncate(self.prior_len));
        if let Some(idx) = self.slot {
            let slot = &self.phases.slots[idx as usize];
            slot.nanos.fetch_add(nanos, Ordering::Relaxed);
            slot.count.fetch_add(1, Ordering::Relaxed);
            if crate::obs::sink::enabled() {
                let path = self.phases.path_of(idx);
                crate::obs::sink::emit_lazy("span", || {
                    vec![
                        ("path", Json::from(path.as_str())),
                        ("us", Json::from(nanos / 1_000)),
                    ]
                });
            }
        }
    }
}

/// One phase's aggregated stats.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PhaseStat {
    /// `/`-joined span path, e.g. `build/rep/sketch`.
    pub path: String,
    /// Span instances recorded.
    pub count: u64,
    /// Inclusive seconds summed over instances (wall for coordinator-side
    /// phases; Σ per-task time for fanned-out phases).
    pub secs: f64,
    /// Explicitly attributed data-parallel worker seconds
    /// ([`PhaseGuard::add_busy`]); 0 where nothing was attributed.
    pub busy_secs: f64,
    /// Explicitly attributed bytes ([`PhaseGuard::add_bytes`]).
    pub bytes: u64,
}

/// Sorted snapshot of a [`Phases`] collector — the `phases` member of
/// `CostReport` and the `BENCH_*` `phases` objects.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PhaseReport {
    /// Per-phase stats, ascending by path.
    pub phases: Vec<PhaseStat>,
    /// Spans dropped because the slot table was full (0 in practice).
    pub dropped: u64,
}

impl PhaseReport {
    /// Stats of an exact path, if recorded.
    pub fn get(&self, path: &str) -> Option<&PhaseStat> {
        self.phases.iter().find(|p| p.path == path)
    }

    /// Σ `secs` over phases whose path matches `path` exactly or lives
    /// under `path/`.
    pub fn subtree_secs(&self, path: &str) -> f64 {
        self.phases
            .iter()
            .filter(|p| p.path == path || p.path.starts_with(&format!("{path}/")))
            .map(|p| p.secs)
            .sum()
    }

    /// JSON object mapping path → `{count, secs, busy_secs, bytes}`; a
    /// `_dropped_spans` key appears only when spans were dropped.
    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = self
            .phases
            .iter()
            .map(|p| {
                (
                    p.path.as_str(),
                    Json::obj(vec![
                        ("count", Json::from(p.count)),
                        ("secs", Json::from(p.secs)),
                        ("busy_secs", Json::from(p.busy_secs)),
                        ("bytes", Json::from(p.bytes)),
                    ]),
                )
            })
            .collect();
        if self.dropped > 0 {
            pairs.push(("_dropped_spans", Json::from(self.dropped)));
        }
        Json::obj(pairs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nesting_builds_paths() {
        let ph = Phases::new();
        {
            let _a = ph.enter("build");
            {
                let _b = ph.enter("rep");
                let _c = ph.enter("sketch");
            }
            let _d = ph.enter("accumulate");
        }
        let r = ph.report();
        let paths: Vec<&str> = r.phases.iter().map(|p| p.path.as_str()).collect();
        assert_eq!(paths, vec!["build", "build/accumulate", "build/rep", "build/rep/sketch"]);
        assert_eq!(r.get("build").unwrap().count, 1);
        assert_eq!(r.dropped, 0);
    }

    #[test]
    fn sibling_instances_do_not_cross() {
        let a = Phases::new();
        let b = Phases::new();
        let _ga = a.enter("outer");
        {
            let _gb = b.enter("other");
            let _ga2 = a.enter("inner");
        }
        drop(_ga);
        let ra = a.report();
        let rb = b.report();
        assert!(ra.get("outer/inner").is_some(), "a-nesting must ignore b's span");
        assert!(rb.get("other").is_some());
        assert!(rb.get("outer/other").is_none());
    }

    #[test]
    fn same_name_different_parent_is_distinct() {
        let ph = Phases::new();
        {
            let _a = ph.enter("rep");
            let _b = ph.enter("sketch");
        }
        {
            let _c = ph.enter("sketch");
        }
        let r = ph.report();
        assert_eq!(r.get("rep/sketch").unwrap().count, 1);
        assert_eq!(r.get("sketch").unwrap().count, 1);
    }

    #[test]
    fn busy_and_bytes_attribution() {
        let ph = Phases::new();
        {
            let g = ph.enter("sketch");
            g.add_busy(2_000_000_000);
            g.add_bytes(4096);
        }
        let r = ph.report();
        let s = r.get("sketch").unwrap();
        assert!((s.busy_secs - 2.0).abs() < 1e-9);
        assert_eq!(s.bytes, 4096);
        assert!(s.secs >= 0.0);
    }

    #[test]
    fn parallel_spans_from_pool_workers() {
        let ph = std::sync::Arc::new(Phases::new());
        let ph2 = ph.clone();
        crate::util::pool::parallel_chunks(64, 4, move |_w, range| {
            for _ in range {
                let g = ph2.enter("build/rep");
                let _inner = ph2.enter("score");
                g.add_busy(1);
            }
        });
        let r = ph.report();
        assert_eq!(r.get("build/rep").unwrap().count, 64);
        assert_eq!(r.get("build/rep/score").unwrap().count, 64);
        assert_eq!(r.dropped, 0);
    }

    #[test]
    fn report_json_parses() {
        let ph = Phases::new();
        {
            let _g = ph.enter("build");
        }
        let j = ph.report().to_json().to_string();
        let v = crate::util::json::parse(&j).unwrap();
        assert_eq!(v.get("build").unwrap().get("count").unwrap().as_usize().unwrap(), 1);
    }
}
