//! Log-bucketed histograms (HDR-style) with deterministic merge.
//!
//! Values are `u64` in whatever unit the caller picks (the serve paths
//! record nanoseconds; counts and widths are recorded raw). The bucket
//! scheme is the classic power-of-2 layout with [`SUB_BUCKETS`] = 16
//! linear sub-buckets per octave:
//!
//! * values `< 16` get their own exact bucket (index = value);
//! * a value `v ≥ 16` with floor-log2 `o` lands in bucket
//!   `(o - 3) · 16 + ((v >> (o - 4)) & 15)` — 16 equal-width sub-buckets
//!   spanning `[2^o, 2^(o+1))`.
//!
//! The relative quantization error is therefore bounded by `1/16`
//! (≤ 6.25%), quantile estimates are clamped to the recorded `[min, max]`,
//! and everything below 16 is exact. [`NUM_BUCKETS`] = 976 covers the full
//! `u64` range.
//!
//! [`Histogram`] is the live, lock-free recorder (relaxed atomic adds —
//! safe to share across pool workers); [`HistSnapshot`] is the plain-data
//! snapshot used for quantiles, JSON reports and merging. Merge is
//! bucket-wise addition: associative, commutative, and exactly
//! count-conserving (asserted by `tests/obs.rs`).

use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};

/// Linear sub-buckets per power-of-2 octave (must stay a power of two).
pub const SUB_BUCKETS: usize = 16;
const SUB_SHIFT: u32 = 4; // log2(SUB_BUCKETS)

/// Total bucket count covering all of `u64` (the largest index, reached at
/// `u64::MAX`, is `(63 - SUB_SHIFT + 1) · SUB_BUCKETS + SUB_BUCKETS - 1`).
pub const NUM_BUCKETS: usize = (64 - SUB_SHIFT as usize + 1) * SUB_BUCKETS;

/// Bucket index of a value. Monotone non-decreasing in `v`.
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v < SUB_BUCKETS as u64 {
        return v as usize;
    }
    let o = 63 - v.leading_zeros(); // floor(log2 v) >= SUB_SHIFT
    ((o - SUB_SHIFT + 1) as usize) * SUB_BUCKETS
        + ((v >> (o - SUB_SHIFT)) as usize & (SUB_BUCKETS - 1))
}

/// Smallest value mapping to bucket `idx` (inverse of [`bucket_index`]).
#[inline]
pub fn bucket_floor(idx: usize) -> u64 {
    if idx < SUB_BUCKETS {
        return idx as u64;
    }
    let o = (idx / SUB_BUCKETS) as u32 - 1 + SUB_SHIFT;
    let sub = (idx % SUB_BUCKETS) as u64;
    (SUB_BUCKETS as u64 + sub) << (o - SUB_SHIFT)
}

/// Exclusive upper bound of bucket `idx` (`u64::MAX` for the last bucket).
#[inline]
pub fn bucket_ceil(idx: usize) -> u64 {
    if idx + 1 >= NUM_BUCKETS {
        u64::MAX
    } else {
        bucket_floor(idx + 1)
    }
}

/// Live lock-free histogram: relaxed atomic bucket counters plus running
/// count/sum/min/max. Recording never blocks and never allocates.
#[derive(Debug)]
pub struct Histogram {
    counts: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    /// Empty histogram.
    pub fn new() -> Histogram {
        Histogram {
            counts: (0..NUM_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
        }
    }

    /// Record one value (lock-free; relaxed ordering).
    #[inline]
    pub fn record(&self, v: u64) {
        self.counts[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Total recorded values.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Plain-data snapshot (sparse; only non-empty buckets are kept).
    pub fn snapshot(&self) -> HistSnapshot {
        let mut buckets = Vec::new();
        for (i, c) in self.counts.iter().enumerate() {
            let n = c.load(Ordering::Relaxed);
            if n > 0 {
                buckets.push((i as u32, n));
            }
        }
        HistSnapshot {
            buckets,
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
            min: self.min.load(Ordering::Relaxed),
            max: self.max.load(Ordering::Relaxed),
        }
    }
}

/// Plain-data histogram snapshot: sparse `(bucket, count)` pairs in
/// ascending bucket order plus exact count/sum/min/max. Also usable as a
/// cheap serial recorder (see [`HistSnapshot::record`]) where no sharing
/// is needed.
#[derive(Clone, Debug, PartialEq)]
pub struct HistSnapshot {
    /// Non-empty `(bucket index, count)` pairs, ascending by index.
    pub buckets: Vec<(u32, u64)>,
    /// Total recorded values.
    pub count: u64,
    /// Sum of recorded values (wrapping on overflow).
    pub sum: u64,
    /// Smallest recorded value (`u64::MAX` when empty).
    pub min: u64,
    /// Largest recorded value (0 when empty).
    pub max: u64,
}

impl Default for HistSnapshot {
    fn default() -> HistSnapshot {
        HistSnapshot { buckets: Vec::new(), count: 0, sum: 0, min: u64::MAX, max: 0 }
    }
}

impl HistSnapshot {
    /// Record one value serially (single-owner paths; the live
    /// [`Histogram`] is the shared-recorder variant).
    pub fn record(&mut self, v: u64) {
        let idx = bucket_index(v) as u32;
        match self.buckets.binary_search_by_key(&idx, |&(i, _)| i) {
            Ok(pos) => self.buckets[pos].1 += 1,
            Err(pos) => self.buckets.insert(pos, (idx, 1)),
        }
        self.count += 1;
        self.sum = self.sum.wrapping_add(v);
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Bucket-wise merge: associative, commutative, count-conserving.
    pub fn merge(&self, other: &HistSnapshot) -> HistSnapshot {
        let mut buckets = Vec::with_capacity(self.buckets.len() + other.buckets.len());
        let (mut i, mut j) = (0, 0);
        while i < self.buckets.len() || j < other.buckets.len() {
            let a = self.buckets.get(i);
            let b = other.buckets.get(j);
            match (a, b) {
                (Some(&(ia, ca)), Some(&(ib, cb))) if ia == ib => {
                    buckets.push((ia, ca + cb));
                    i += 1;
                    j += 1;
                }
                (Some(&(ia, ca)), Some(&(ib, _))) if ia < ib => {
                    buckets.push((ia, ca));
                    i += 1;
                }
                (Some(_), Some(&(ib, cb))) => {
                    buckets.push((ib, cb));
                    j += 1;
                }
                (Some(&(ia, ca)), None) => {
                    buckets.push((ia, ca));
                    i += 1;
                }
                (None, Some(&(ib, cb))) => {
                    buckets.push((ib, cb));
                    j += 1;
                }
                (None, None) => unreachable!(),
            }
        }
        HistSnapshot {
            buckets,
            count: self.count + other.count,
            sum: self.sum.wrapping_add(other.sum),
            min: self.min.min(other.min),
            max: self.max.max(other.max),
        }
    }

    /// Quantile estimate for `q ∈ [0, 1]` (nearest-rank over buckets, the
    /// bucket midpoint clamped to the exact `[min, max]`). 0 when empty.
    /// Monotone non-decreasing in `q`; exact for values below
    /// [`SUB_BUCKETS`], within 6.25% relative error elsewhere.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for &(idx, c) in &self.buckets {
            cum += c;
            if cum >= rank {
                let idx = idx as usize;
                let floor = bucket_floor(idx);
                let width = bucket_ceil(idx).saturating_sub(floor);
                let mid = floor + width / 2;
                return mid.clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Mean of recorded values (0.0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// JSON object: count/sum/min/max plus p50/p90/p99/p999 estimates.
    /// `min` is reported as 0 when empty.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::from(self.count)),
            ("sum", Json::from(self.sum)),
            ("min", Json::from(if self.count == 0 { 0 } else { self.min })),
            ("max", Json::from(self.max)),
            ("p50", Json::from(self.quantile(0.50))),
            ("p90", Json::from(self.quantile(0.90))),
            ("p99", Json::from(self.quantile(0.99))),
            ("p999", Json::from(self.quantile(0.999))),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_is_monotone_and_inverts() {
        let mut prev = 0usize;
        let mut v = 0u64;
        while v < 1 << 40 {
            let idx = bucket_index(v);
            assert!(idx >= prev, "bucket index not monotone at {v}");
            assert!(bucket_floor(idx) <= v, "floor({idx}) > {v}");
            assert!(v < bucket_ceil(idx) || idx + 1 == NUM_BUCKETS);
            prev = idx;
            v = v * 2 + 1;
        }
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(15), 15);
        assert_eq!(bucket_index(16), 16);
        assert_eq!(bucket_index(31), 31);
        assert_eq!(bucket_index(32), 32);
        assert!(bucket_index(u64::MAX) < NUM_BUCKETS);
    }

    #[test]
    fn small_values_are_exact() {
        let h = Histogram::new();
        for v in 0..16u64 {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 16);
        for (i, q) in (0..16).map(|i| (i, (i as f64 + 1.0) / 16.0)) {
            assert_eq!(s.quantile(q), i as u64, "quantile {q}");
        }
    }

    #[test]
    fn relative_error_bounded() {
        let mut s = HistSnapshot::default();
        let v = 123_456_789u64;
        s.record(v);
        let est = s.quantile(0.5);
        let err = (est as f64 - v as f64).abs() / v as f64;
        assert!(err <= 1.0 / SUB_BUCKETS as f64, "relative error {err}");
    }

    #[test]
    fn quantiles_monotone_and_clamped() {
        let mut s = HistSnapshot::default();
        let mut x = 1u64;
        for _ in 0..1000 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            s.record(x >> 40);
        }
        let mut prev = 0;
        for q in [0.0, 0.1, 0.5, 0.9, 0.99, 0.999, 1.0] {
            let v = s.quantile(q);
            assert!(v >= prev);
            assert!(v >= s.min && v <= s.max);
            prev = v;
        }
    }

    #[test]
    fn merge_conserves_counts() {
        let (mut a, mut b) = (HistSnapshot::default(), HistSnapshot::default());
        for v in 0..500u64 {
            a.record(v * 7);
            b.record(v * 13 + 3);
        }
        let m = a.merge(&b);
        assert_eq!(m.count, 1000);
        assert_eq!(m.buckets.iter().map(|&(_, c)| c).sum::<u64>(), 1000);
        assert_eq!(m.sum, a.sum.wrapping_add(b.sum));
        assert_eq!(m.min, a.min.min(b.min));
        assert_eq!(m.max, a.max.max(b.max));
    }

    #[test]
    fn empty_snapshot_behaves() {
        let s = HistSnapshot::default();
        assert_eq!(s.quantile(0.5), 0);
        assert_eq!(s.mean(), 0.0);
        let j = s.to_json().to_string();
        let v = crate::util::json::parse(&j).unwrap();
        assert_eq!(v.get("count").unwrap().as_usize().unwrap(), 0);
        assert_eq!(v.get("min").unwrap().as_usize().unwrap(), 0);
    }

    #[test]
    fn atomic_and_serial_recorders_agree() {
        let h = Histogram::new();
        let mut s = HistSnapshot::default();
        for v in [0u64, 5, 17, 900, 1 << 20, u64::MAX] {
            h.record(v);
            s.record(v);
        }
        assert_eq!(h.snapshot(), s);
    }
}
