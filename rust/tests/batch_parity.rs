//! Batch/scalar parity property tests for every `Similarity` implementation,
//! plus the sharded-vs-serial `Accumulator` equivalence test.
//!
//! The tiled kernels in `sim::batch` must agree with the per-pair scalar
//! path: exactly for cosine/dot/jaccard/mixture (same reduction order by
//! construction) and to 1e-6 for weighted Jaccard (the denominator is summed
//! in a different order). `LearnedSim` is excluded — it needs PJRT artifacts
//! and its `sim_batch` is a single model dispatch either way.

use stars::data::synth;
use stars::data::types::{Dataset, WeightedSet};
use stars::graph::Edge;
use stars::sim::{
    CosineSim, CountingSim, DotSim, JaccardSim, MixtureSim, Similarity, WeightedJaccardSim,
};
use stars::stars::Accumulator;
use stars::util::quickcheck::{check, Gen};
use stars::util::rng::Rng;

/// Assert `sim_batch` == per-pair `sim` to within `tol` for one measure.
fn assert_parity(sim: &dyn Similarity, ds: &Dataset, leader: usize, cands: &[u32], tol: f32) {
    let mut out = Vec::new();
    sim.sim_batch(ds, leader, cands, &mut out);
    assert_eq!(out.len(), cands.len(), "{}: wrong output len", sim.name());
    for (k, &c) in cands.iter().enumerate() {
        let want = sim.sim(ds, leader, c as usize);
        assert!(
            (out[k] - want).abs() <= tol,
            "{}: leader {leader} cand {c}: batch {} vs scalar {want}",
            sim.name(),
            out[k]
        );
    }
}

/// Random dense dataset; dimension sweeps past the 8-lane chunk and the
/// 4-row block boundaries.
fn dense_dataset(g: &mut Gen) -> Dataset {
    let n = g.usize_in(2, 80);
    let d = g.usize_in(1, 130);
    let mut rows = Vec::with_capacity(n * d);
    for _ in 0..n {
        rows.extend(g.vec_f32(d));
    }
    Dataset::from_dense("parity", d, rows, Vec::new())
}

/// Random set dataset (some sets empty, to hit the 0/0 conventions).
fn set_dataset(g: &mut Gen) -> Dataset {
    let n = g.usize_in(2, 60);
    let mut sets = Vec::with_capacity(n);
    for _ in 0..n {
        if g.bool(0.1) {
            sets.push(WeightedSet::default());
        } else {
            let tokens = g.subset(64, 12);
            let pairs: Vec<(u32, f32)> =
                tokens.into_iter().map(|t| (t, g.f32_in(0.0, 2.0))).collect();
            sets.push(WeightedSet::from_pairs(pairs));
        }
    }
    Dataset::from_sets("parity-sets", sets, Vec::new())
}

/// Candidate list over a dataset: scattered order, may repeat, may include
/// the leader itself (the scoring loops never pass it, but the kernel
/// contract does not forbid it).
fn candidates(g: &mut Gen, n: usize) -> Vec<u32> {
    let m = g.usize_in(1, 2 * n.max(2));
    (0..m).map(|_| g.usize_in(0, n - 1) as u32).collect()
}

#[test]
fn cosine_batch_parity() {
    check("cosine-parity", 60, |g| {
        let ds = dense_dataset(g);
        let leader = g.usize_in(0, ds.len() - 1);
        let cands = candidates(g, ds.len());
        assert_parity(&CosineSim, &ds, leader, &cands, 1e-6);
    });
}

#[test]
fn dot_batch_parity() {
    check("dot-parity", 60, |g| {
        let ds = dense_dataset(g);
        let leader = g.usize_in(0, ds.len() - 1);
        let cands = candidates(g, ds.len());
        assert_parity(&DotSim, &ds, leader, &cands, 1e-6);
    });
}

#[test]
fn jaccard_batch_parity() {
    check("jaccard-parity", 60, |g| {
        let ds = set_dataset(g);
        let leader = g.usize_in(0, ds.len() - 1);
        let cands = candidates(g, ds.len());
        assert_parity(&JaccardSim, &ds, leader, &cands, 1e-6);
    });
}

#[test]
fn weighted_jaccard_batch_parity() {
    check("weighted-jaccard-parity", 60, |g| {
        let ds = set_dataset(g);
        let leader = g.usize_in(0, ds.len() - 1);
        let cands = candidates(g, ds.len());
        assert_parity(&WeightedJaccardSim, &ds, leader, &cands, 1e-6);
    });
}

#[test]
fn mixture_batch_parity() {
    check("mixture-parity", 40, |g| {
        // Hybrid dataset: the products generator carries embeddings + sets.
        let n = g.usize_in(4, 60);
        let ds = synth::products(n, &synth::ProductsParams::default(), g.usize_in(0, 1 << 30) as u64);
        let leader = g.usize_in(0, ds.len() - 1);
        let cands = candidates(g, ds.len());
        let alpha = g.f32_in(0.0, 1.0);
        assert_parity(&MixtureSim { alpha }, &ds, leader, &cands, 1e-6);
    });
}

#[test]
fn counting_sim_batch_parity_and_count() {
    check("counting-parity", 30, |g| {
        let ds = dense_dataset(g);
        let leader = g.usize_in(0, ds.len() - 1);
        let cands = candidates(g, ds.len());
        let cs = CountingSim::new(CosineSim);
        let mut out = Vec::new();
        cs.sim_batch(&ds, leader, &cands, &mut out);
        assert_eq!(cs.comparisons(), cands.len() as u64);
        for (k, &c) in cands.iter().enumerate() {
            let want = CosineSim.sim(&ds, leader, c as usize);
            assert!((out[k] - want).abs() <= 1e-6);
        }
    });
}

/// Naive reference for the degree-capped accumulator: dedup to the max
/// weight per pair, then keep each node's `cap` strongest neighbors; an edge
/// survives if either endpoint retains it. Assumes distinct weights.
fn reference_graph(n: usize, cap: usize, batches: &[Vec<Edge>]) -> Vec<(u32, u32)> {
    use std::collections::HashMap;
    let mut best: HashMap<(u32, u32), f32> = HashMap::new();
    for b in batches {
        for e in b {
            let w = best.entry((e.u, e.v)).or_insert(f32::NEG_INFINITY);
            if e.w > *w {
                *w = e.w;
            }
        }
    }
    let mut per_node: Vec<Vec<(f32, u32)>> = vec![Vec::new(); n];
    for (&(u, v), &w) in &best {
        per_node[u as usize].push((w, v));
        per_node[v as usize].push((w, u));
    }
    let mut kept: std::collections::BTreeSet<(u32, u32)> = Default::default();
    for (node, nbrs) in per_node.iter_mut().enumerate() {
        nbrs.sort_by(|a, b| b.0.total_cmp(&a.0));
        for &(_, nbr) in nbrs.iter().take(cap) {
            let (a, b) = (node as u32, nbr);
            kept.insert((a.min(b), a.max(b)));
        }
    }
    kept.into_iter().collect()
}

#[test]
fn accumulator_sharded_matches_serial_and_reference() {
    // Fixed seed; weights unique by construction so f32 ties cannot mask
    // ordering differences between the sharded and serial folds.
    let mut rng = Rng::new(0x5EED);
    let n = 400usize;
    let cap = 4usize;
    let mut batches: Vec<Vec<Edge>> = Vec::new();
    let mut uniq = 0u32;
    for _ in 0..6 {
        let mut batch = Vec::new();
        for _ in 0..3000 {
            let u = rng.below(n) as u32;
            let mut v = rng.below(n) as u32;
            if u == v {
                v = (v + 1) % n as u32;
            }
            uniq += 1;
            batch.push(Edge::new(u, v, uniq as f32 * 1e-6));
        }
        batches.push(batch);
    }

    let mut sharded = Accumulator::with_workers(n, cap, 8);
    sharded.add_wave(batches.clone());
    let g_sharded = sharded.finalize();

    let mut serial = Accumulator::with_workers(n, cap, 1);
    for b in batches.clone() {
        serial.add(b);
    }
    let g_serial = serial.finalize();

    assert_eq!(g_sharded.edges(), g_serial.edges(), "sharded != serial");

    let want = reference_graph(n, cap, &batches);
    let got: Vec<(u32, u32)> = g_sharded.edges().iter().map(|e| (e.u, e.v)).collect();
    assert_eq!(got.len(), want.len(), "edge count vs reference");
    assert_eq!(got, want, "edge set vs naive top-cap reference");
}
