//! Observability acceptance tests (ARCHITECTURE.md "Observability"):
//!
//! * the build's span tree has the documented shape under every worker
//!   count — no orphaned or crossed spans from pool parallelism, and the
//!   straggler-safe root anchoring keeps `build/rep` on one path;
//! * histogram merge is associative and exactly count-conserving;
//! * tracing is observation only — a traced build/serve run is
//!   bit-identical (edges and top-k) to an untraced one, every NDJSON line
//!   the sink writes parses back through `util::json`, and `1/N` sampling
//!   keeps exactly the events whose global index survives `seq % N == 0`;
//! * `CostReport::phases` reconciles with the report's wall/busy clocks;
//! * `run_serve_with(metrics_out)` leaves a parseable Prometheus-text
//!   snapshot behind.
//!
//! The sink is process-global, so everything that toggles it lives in ONE
//! test fn (`tracing_is_observation_only_and_ndjson_parses`) — the other
//! tests never enable it, and stray span events from concurrently running
//! builds landing in the trace file are themselves valid events, which the
//! parse assertions tolerate by design.

use stars::data::synth;
use stars::lsh::SimHash;
use stars::obs::HistSnapshot;
use stars::serve::{QueryEngine, ServeConfig, ServeMeasure};
use stars::sim::CosineSim;
use stars::stars::{Algorithm, BuildParams, StarsBuilder};

const REPS: usize = 12;

fn fixture() -> (stars::data::Dataset, SimHash) {
    let ds = synth::gaussian_mixture(1200, 16, 10, 0.1, 21);
    let h = SimHash::new(16, 8, 3);
    (ds, h)
}

fn params() -> BuildParams {
    BuildParams::threshold_mode(Algorithm::LshStars)
        .sketches(REPS)
        .threshold(0.5)
}

#[test]
fn span_tree_is_stable_under_every_worker_count() {
    let (ds, h) = fixture();
    for workers in [1usize, 2, 4, 8] {
        let out = StarsBuilder::new(&ds)
            .similarity(&CosineSim)
            .hash(&h)
            .params(params())
            .workers(workers)
            .build();
        let ph = &out.report.phases;
        assert_eq!(ph.dropped, 0, "dropped spans at {workers} workers");
        // Coordinator-side spine.
        assert_eq!(ph.get("build").unwrap().count, 1, "{workers} workers");
        let waves = ph.get("build/wave").unwrap().count;
        assert!(waves >= 1, "no waves at {workers} workers");
        assert_eq!(ph.get("build/accumulate").unwrap().count, waves);
        assert_eq!(ph.get("build/finalize").unwrap().count, 1);
        // Per-repetition subtree: root-anchored, so the count is exactly R
        // for every worker count — a crossed span (a rep nested under
        // wave, or a phase attributed to the wrong rep) would split these
        // counts across paths.
        for path in [
            "build/rep",
            "build/rep/sketch",
            "build/rep/join",
            "build/rep/score",
        ] {
            assert_eq!(
                ph.get(path).map(|p| p.count),
                Some(REPS as u64),
                "{path} at {workers} workers"
            );
        }
        // No orphans: every recorded path lives in the build namespace.
        for p in &ph.phases {
            assert!(
                p.path == "build" || p.path.starts_with("build/"),
                "orphaned span path {:?} at {workers} workers",
                p.path
            );
        }
    }
}

#[test]
fn histogram_merge_is_associative_and_conserves_counts() {
    let mk = |vals: &[u64]| {
        let mut s = HistSnapshot::default();
        for &v in vals {
            s.record(v);
        }
        s
    };
    let a = mk(&[0, 1, 5, 17, 300, 301, 1 << 30]);
    let b = mk(&[2, 4, 1_000_000, u64::MAX]);
    let c = mk(&[7, 7, 7, 123_456_789]);
    let left = a.merge(&b).merge(&c);
    let right = a.merge(&b.merge(&c));
    assert_eq!(left, right, "merge must be associative");
    assert_eq!(a.merge(&b), b.merge(&a), "merge must be commutative");
    // Exact count conservation, in the total and bucket-wise.
    assert_eq!(left.count, a.count + b.count + c.count);
    assert_eq!(
        left.buckets.iter().map(|&(_, n)| n).sum::<u64>(),
        left.count
    );
    assert_eq!(left.min, 0);
    assert_eq!(left.max, u64::MAX);
    // Identity element.
    assert_eq!(a.merge(&HistSnapshot::default()), a);
}

#[test]
fn tracing_is_observation_only_and_ndjson_parses() {
    let (ds, h) = fixture();
    let build = || {
        StarsBuilder::new(&ds)
            .similarity(&CosineSim)
            .hash(&h)
            .params(params())
            .workers(4)
            .build_indexed(ServeConfig::default().route_reps(4).compact_limit(0))
    };
    let qids: Vec<u32> = (0..1200u32).step_by(24).collect();
    let queries = ds.subset(&qids);

    // Baseline: tracing off.
    stars::obs::set_trace(None, 1).unwrap();
    let (out_off, index_off) = build();
    let engine_off =
        QueryEngine::new(index_off, &h, ServeMeasure::Cosine, params()).workers(4);
    let topk_off = engine_off.query(&queries, 10);

    // Same build + sweep, traced.
    let dir = std::env::temp_dir();
    let trace = dir.join(format!("stars_obs_trace_{}.ndjson", std::process::id()));
    let _ = std::fs::remove_file(&trace);
    stars::obs::set_trace(Some(trace.as_path()), 1).unwrap();
    let (out_on, index_on) = build();
    let engine_on =
        QueryEngine::new(index_on, &h, ServeMeasure::Cosine, params()).workers(4);
    let topk_on = engine_on.query(&queries, 10);
    stars::obs::set_trace(None, 1).unwrap();

    // Bit-identity: tracing must not change edges or top-k.
    assert_eq!(
        out_off.graph.edges(),
        out_on.graph.edges(),
        "tracing changed the built edges"
    );
    assert_eq!(topk_off, topk_on, "tracing changed serve top-k");

    // Every line the sink wrote parses back and is a tagged object.
    let text = std::fs::read_to_string(&trace).unwrap();
    let mut spans = 0usize;
    let mut queries_seen = 0usize;
    for (i, line) in text.lines().filter(|l| !l.trim().is_empty()).enumerate() {
        let doc = stars::util::json::parse(line)
            .unwrap_or_else(|e| panic!("line {}: {e}: {line}", i + 1));
        let kind = doc.get("kind").and_then(|k| k.as_str()).unwrap().to_string();
        assert!(doc.get("seq").is_some(), "line {} has no seq", i + 1);
        match kind.as_str() {
            "span" => {
                spans += 1;
                assert!(doc.get("path").and_then(|p| p.as_str()).is_some());
            }
            "serve_query" => queries_seen += 1,
            _ => {}
        }
    }
    assert!(spans > 0, "traced build emitted no span events");
    assert!(queries_seen > 0, "traced sweep emitted no serve_query events");

    // Deterministic 1/N sampling: with sample_every = 3, every surviving
    // event's global index satisfies seq % 3 == 0 — no RNG anywhere.
    let sampled = dir.join(format!("stars_obs_sampled_{}.ndjson", std::process::id()));
    let _ = std::fs::remove_file(&sampled);
    stars::obs::set_trace(Some(sampled.as_path()), 3).unwrap();
    assert_eq!(stars::obs::sample_every(), 3);
    for _ in 0..30 {
        stars::obs::emit("marker", vec![("x", stars::util::json::Json::from(1u64))]);
    }
    stars::obs::set_trace(None, 1).unwrap();
    let text = std::fs::read_to_string(&sampled).unwrap();
    let mut kept = 0usize;
    for line in text.lines().filter(|l| !l.trim().is_empty()) {
        let doc = stars::util::json::parse(line).unwrap();
        let seq = doc.get("seq").unwrap().as_usize().unwrap();
        assert_eq!(seq % 3, 0, "sampled event with off-stride seq {seq}");
        kept += 1;
    }
    assert!(kept > 0, "1/3 sampling of 30 events kept nothing");
    let _ = std::fs::remove_file(&trace);
    let _ = std::fs::remove_file(&sampled);
}

#[test]
fn phases_reconcile_with_cost_report_clocks() {
    let (ds, h) = fixture();
    // REPS >= workers so each repetition runs with inner_workers == 1 and
    // the rep spans' Σ wall is directly comparable to total_time.
    let out = StarsBuilder::new(&ds)
        .similarity(&CosineSim)
        .hash(&h)
        .params(params())
        .workers(4)
        .build();
    let r = &out.report;
    const SLACK_S: f64 = 0.5;
    let build = r.phases.get("build").unwrap();
    assert!(build.secs > 0.0);
    // The build root span lives inside the job wall clock.
    assert!(
        build.secs <= r.real_time + SLACK_S,
        "build span {:.3}s exceeds wall {:.3}s",
        build.secs,
        r.real_time
    );
    // Σ per-rep task time is what the ledger charges as worker busy time,
    // so the rep subtree cannot exceed total_time by more than accounting
    // slack (rep spans include a sliver of per-task bookkeeping the
    // ledger's own charge also includes).
    let rep_secs = r.phases.get("build/rep").unwrap().secs;
    assert!(
        rep_secs <= r.total_time + SLACK_S,
        "rep spans {rep_secs:.3}s exceed total busy {:.3}s",
        r.total_time
    );
    // Phase children stay inside their parent's inclusive time.
    let child_sum: f64 = ["build/rep/sketch", "build/rep/join", "build/rep/score"]
        .iter()
        .map(|p| r.phases.get(p).unwrap().secs)
        .sum();
    assert!(
        child_sum <= rep_secs + SLACK_S,
        "children {child_sum:.3}s exceed build/rep {rep_secs:.3}s"
    );
    // The report JSON carries the phases object.
    let j = r.to_json().to_string();
    let doc = stars::util::json::parse(&j).unwrap();
    let phases = doc.get("phases").expect("report JSON lost phases");
    assert!(phases.get("build").is_some());
}

#[test]
fn metrics_out_writes_prometheus_snapshot() {
    use stars::coordinator::{DatasetSpec, FamilySpec, Job, MeasureSpec, ServeOpts};
    let job = Job {
        dataset: DatasetSpec::Random {
            n: 400,
            dim: 16,
            modes: 8,
        },
        measure: MeasureSpec::Cosine,
        family: FamilySpec::SimHash { bits: 8 },
        params: BuildParams::threshold_mode(Algorithm::LshStars)
            .sketches(6)
            .threshold(0.4),
        data_seed: 7,
        workers: 2,
    };
    let path = std::env::temp_dir().join(format!(
        "stars_obs_metrics_{}.prom",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&path);
    let opts = ServeOpts {
        queries: 10,
        k: 5,
        metrics_out: Some(path.clone()),
        metrics_every_s: 0.05,
        ..ServeOpts::default()
    };
    let doc = stars::coordinator::run_serve_with(&job, &opts).unwrap();
    // The serve JSON now reports the full quantile ladder from the obs
    // histogram.
    for key in ["p50_ms", "p90_ms", "p99_ms", "p999_ms"] {
        assert!(doc.get(key).unwrap().as_f64().unwrap() >= 0.0, "{key}");
    }
    // The exporter's final write (on drop) leaves a Prometheus-text
    // snapshot behind, and the rename-into-place protocol leaves no .tmp.
    let text = std::fs::read_to_string(&path).expect("metrics snapshot missing");
    assert!(text.contains("# TYPE"), "no TYPE lines:\n{text}");
    assert!(
        text.contains("stars_serve_query_latency_us"),
        "latency summary missing:\n{text}"
    );
    assert!(text.contains("stars_serve_queries_total"));
    let _ = std::fs::remove_file(&path);
}
