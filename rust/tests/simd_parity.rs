//! SIMD-vs-scalar bit-parity suite, plus parallel-radix permutation
//! equality — the enforcement arm of the instruction-set-invariance
//! contract (ARCHITECTURE.md §SIMD dispatch).
//!
//! Every kernel ported onto `util::simd` must produce **bit-identical**
//! output on every backend reachable on the build host: similarity scores,
//! sketch keys, and therefore edges and served top-k lists can never depend
//! on which lanes computed them. The sweep covers the acceptance dimensions
//! {3, 8, 16, 100, 784} — hitting every lane-count/tail combination (d=3 is
//! pure tail, d=8 one dot chunk, d=100 chunks+tail, d=784 the MNIST row).
//!
//! The forced override is exercised two ways: `resolve("scalar")` is pinned
//! here, and `scripts/ci.sh` runs this whole suite (and every other test)
//! twice — default dispatch and `STARS_SIMD=scalar` — so the dispatched
//! entry points are themselves validated under both resolutions.

use stars::data::synth;
use stars::lsh::sketch::{sketch_row_with, sketch_tile_with};
use stars::sim::batch::dot_tile_with;
use stars::util::radix;
use stars::util::rng::Rng;
use stars::util::simd::{self, SimdBackend};

const DIMS: [usize; 5] = [3, 8, 16, 100, 784];

fn rows(n: usize, d: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n * d).map(|_| rng.gaussian() as f32).collect()
}

#[test]
fn forced_scalar_override_resolves_to_scalar() {
    // The env-var policy itself (resolve() is the pure core active() caches):
    assert_eq!(simd::resolve(Some("scalar")), SimdBackend::Scalar);
    // And when the driver runs this suite under STARS_SIMD=..., the active
    // backend must be exactly what the override names.
    if let Ok(forced) = std::env::var(simd::SIMD_ENV) {
        let want = match SimdBackend::parse(&forced) {
            Some(b) if simd::supported(b) => b,
            Some(_) => SimdBackend::Scalar,
            None => simd::detected(),
        };
        assert_eq!(simd::active(), want, "STARS_SIMD={forced} not honored");
    }
}

#[test]
fn every_reachable_backend_is_listed_and_supported() {
    let backends = simd::reachable();
    assert_eq!(backends[0], SimdBackend::Scalar);
    assert!(backends.iter().all(|&b| simd::supported(b)));
    assert!(backends.contains(&simd::detected()));
}

#[test]
fn dot_kernels_bit_identical_across_backends() {
    for backend in simd::reachable() {
        for &d in &DIMS {
            let a = rows(1, d, 11 + d as u64);
            let b = rows(1, d, 77 + d as u64);
            assert_eq!(
                simd::dot_with(backend, &a, &b).to_bits(),
                simd::dot_with(SimdBackend::Scalar, &a, &b).to_bits(),
                "dot {backend:?} d={d}"
            );
            let t = rows(4, d, 5 + d as u64);
            let (t0, t1, t2, t3) = (&t[..d], &t[d..2 * d], &t[2 * d..3 * d], &t[3 * d..4 * d]);
            let got = simd::dot_block4_with(backend, &a, t0, t1, t2, t3);
            let want = simd::dot_block4_with(SimdBackend::Scalar, &a, t0, t1, t2, t3);
            assert_eq!(
                got.map(f32::to_bits),
                want.map(f32::to_bits),
                "dot_block4 {backend:?} d={d}"
            );
        }
    }
}

#[test]
fn sketch_kernels_bit_identical_across_backends() {
    for backend in simd::reachable() {
        for &d in &DIMS {
            let p0 = rows(1, d, 21 + d as u64);
            let p1 = rows(1, d, 22 + d as u64);
            let t = rows(4, d, 23 + d as u64);
            let (t0, t1, t2, t3) = (&t[..d], &t[d..2 * d], &t[2 * d..3 * d], &t[3 * d..4 * d]);
            let got = simd::sketch_row2_with(backend, &p0, &p1, t0);
            let want = simd::sketch_row2_with(SimdBackend::Scalar, &p0, &p1, t0);
            assert_eq!(
                (got.0.to_bits(), got.1.to_bits()),
                (want.0.to_bits(), want.1.to_bits()),
                "sketch_row2 {backend:?} d={d}"
            );
            let got = simd::sketch_block4_with(backend, &p0, &p1, t0, t1, t2, t3);
            let want = simd::sketch_block4_with(SimdBackend::Scalar, &p0, &p1, t0, t1, t2, t3);
            assert_eq!(
                (got.0.map(f32::to_bits), got.1.map(f32::to_bits)),
                (want.0.map(f32::to_bits), want.1.map(f32::to_bits)),
                "sketch_block4 {backend:?} d={d}"
            );
        }
    }
}

#[test]
fn sum_fold_bit_identical_across_backends() {
    for backend in simd::reachable() {
        for n in [0usize, 1, 3, 4, 5, 8, 100, 784, 1023] {
            let xs = rows(1, n, 31 + n as u64);
            assert_eq!(
                simd::sum_f32_with(backend, &xs).to_bits(),
                simd::sum_f32_with(SimdBackend::Scalar, &xs).to_bits(),
                "sum_f32 {backend:?} n={n}"
            );
        }
    }
}

#[test]
fn dot_tile_bit_identical_across_backends() {
    // Tile-level parity: block path + tail rows, over the dimension sweep.
    for backend in simd::reachable() {
        for &d in &DIMS {
            let n = 13; // two 4-blocks + a 1-row tail after the gather
            let tile = rows(n, d, 41 + d as u64);
            let leader = rows(1, d, 42 + d as u64);
            let mut got = vec![0f32; n];
            let mut want = vec![0f32; n];
            dot_tile_with(backend, &leader, &tile, n, &mut got);
            dot_tile_with(SimdBackend::Scalar, &leader, &tile, n, &mut want);
            for r in 0..n {
                assert_eq!(
                    got[r].to_bits(),
                    want[r].to_bits(),
                    "dot_tile {backend:?} d={d} row={r}"
                );
            }
        }
    }
}

#[test]
fn sketch_tile_keys_bit_identical_across_backends() {
    // Key-level parity: the sign of every plane dot agrees on every
    // backend, for odd and even bit counts and tail rows.
    for backend in simd::reachable() {
        for &(bits, d) in &[(1usize, 3usize), (7, 8), (12, 16), (16, 100), (30, 784)] {
            let n = 11;
            let planes = rows(bits, d, 51 + d as u64);
            let data = rows(n, d, 52 + d as u64);
            let mut got = vec![0u64; n];
            let mut want = vec![0u64; n];
            sketch_tile_with(backend, &planes, bits, d, &data, n, &mut got);
            sketch_tile_with(SimdBackend::Scalar, &planes, bits, d, &data, n, &mut want);
            assert_eq!(got, want, "sketch_tile {backend:?} bits={bits} d={d}");
            for r in 0..n {
                let row_key =
                    sketch_row_with(backend, &planes, bits, d, &data[r * d..(r + 1) * d]);
                assert_eq!(row_key, want[r], "sketch_row {backend:?} bits={bits} row={r}");
            }
        }
    }
}

#[test]
fn dispatched_scoring_is_backend_consistent_end_to_end() {
    // The dispatched entry points (whatever STARS_SIMD / detection picked)
    // must agree bit-for-bit with the forced-scalar tile on real data —
    // this is the assertion that makes the double CI run meaningful.
    let ds = synth::gaussian_mixture(64, 100, 4, 0.2, 9);
    let d = ds.dim();
    let leader = ds.row(0);
    let n = 63;
    let mut tile = vec![0f32; n * d];
    for r in 0..n {
        tile[r * d..(r + 1) * d].copy_from_slice(ds.row(r + 1));
    }
    let mut got = vec![0f32; n];
    let mut want = vec![0f32; n];
    stars::sim::batch::dot_tile(leader, &tile, n, &mut got);
    dot_tile_with(SimdBackend::Scalar, leader, &tile, n, &mut want);
    for r in 0..n {
        assert_eq!(got[r].to_bits(), want[r].to_bits(), "row {r}");
    }
}

// ---------------------------------------------------------------------------
// Parallel radix argsort: permutation equality with the serial sort.
// ---------------------------------------------------------------------------

/// Key sets covering the radix edge cases: uniform, heavy ties (8 distinct
/// values), high-byte-only (late passes), shared-nonzero-byte (OR/AND mask
/// skip), and fully degenerate.
fn radix_cases(n: usize) -> Vec<(&'static str, Vec<u64>)> {
    let mut rng = Rng::new(77);
    vec![
        ("uniform", (0..n).map(|_| rng.next_u64()).collect()),
        ("heavy-ties", (0..n).map(|_| rng.next_u64() % 8).collect()),
        ("high-byte-only", (0..n).map(|_| rng.next_u64() << 56).collect()),
        (
            "shared-mid-byte",
            (0..n)
                .map(|_| (rng.next_u64() & 0xFFFF) | (0xABu64 << 24))
                .collect(),
        ),
        ("all-equal", vec![42u64; n]),
    ]
}

#[test]
fn argsort_par_matches_serial_permutation() {
    // Large enough to clear the parallel cutoffs (RADIX_PAR_MIN_N = 64Ki)
    // so workers > 1 really exercises the histogram + prefix-scatter path.
    for (name, keys) in radix_cases(70_000) {
        let serial = radix::argsort_u64(&keys);
        // Reference semantics: stable by (key, index).
        let mut reference: Vec<u32> = (0..keys.len() as u32).collect();
        reference.sort_unstable_by_key(|&i| (keys[i as usize], i));
        assert_eq!(serial, reference, "{name}: serial vs comparison");
        for workers in [1usize, 2, 4, 8] {
            assert_eq!(
                radix::argsort_u64_par(&keys, workers),
                serial,
                "{name}: workers={workers}"
            );
        }
    }
}

#[test]
fn argsort_par_reports_busy_spans() {
    let mut rng = Rng::new(3);
    let keys: Vec<u64> = (0..70_000).map(|_| rng.next_u64()).collect();
    let spans = std::sync::Mutex::new(Vec::new());
    let order = radix::argsort_u64_par_timed(&keys, 4, |w, ns| {
        spans.lock().unwrap().push((w, ns));
    });
    assert_eq!(order, radix::argsort_u64(&keys));
    let spans = spans.into_inner().unwrap();
    assert!(!spans.is_empty());
    assert!(spans.iter().all(|&(w, _)| w < 4), "worker index out of range");
}
