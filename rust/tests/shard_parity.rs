//! Shard-invariance battery: the scatter-gather [`ShardedEngine`] must
//! answer **bit-identically** to the single-process [`QueryEngine`] over
//! the same snapshot and the same inserts — for every shard count, every
//! worker count, on both scoring tiers (exact f32 and quantized int8), and
//! across the whole write path (live deltas, incremental compaction).
//!
//! The contract holds under `max_candidates = 0` (the global candidate cap
//! truncates in probe order, which no fence partition can replicate);
//! `build_sharded` forces that config and the reference engines here pin
//! it explicitly. See ARCHITECTURE.md "Sharded serving".

use stars::data::synth;
use stars::lsh::SimHash;
use stars::serve::{
    fence_for, CompactionMode, QueryEngine, ServeConfig, ServeMeasure, ShardedEngine,
    ShardedIndex, StarIndex,
};
use stars::sim::CosineSim;
use stars::stars::{Algorithm, BuildParams, StarsBuilder};

fn clustered_params() -> BuildParams {
    BuildParams::threshold_mode(Algorithm::LshStars)
        .sketches(8)
        .threshold(0.5)
}

/// The shared serve config of every engine in this battery: uncapped
/// candidate walk (the shard-invariance requirement), manual compaction,
/// incremental mode, optionally the quantized first-pass tier.
fn serve_cfg(quantized: bool) -> ServeConfig {
    let cfg = ServeConfig::default()
        .route_reps(8)
        .compact_limit(0)
        .max_candidates(0)
        .compaction(CompactionMode::Incremental);
    if quantized {
        cfg.quantized(4)
    } else {
        cfg
    }
}

#[test]
fn sharded_answers_are_bit_identical_to_single_shard() {
    // The full battery: shards {1, 2, 3, 8} × workers {1, 8} × tiers
    // {exact, quantized}, each compared against a single-worker
    // QueryEngine reference at three write-path stages — snapshot-only,
    // with a live 24-point delta, and after one incremental compaction.
    let ds = synth::gaussian_mixture(700, 16, 14, 0.08, 33);
    let extra = synth::gaussian_mixture(24, 16, 14, 0.08, 34);
    let h = SimHash::new(16, 8, 7);
    let qids: Vec<u32> = (0..700u32).step_by(17).collect();
    let queries = ds.subset(&qids);
    let dqueries = extra.subset(&[0, 5, 11, 23]);
    for quantized in [false, true] {
        let tier = if quantized { "quantized" } else { "exact" };
        // Reference: the single-shard engine under the identical config.
        let (_, rindex) = StarsBuilder::new(&ds)
            .similarity(&CosineSim)
            .hash(&h)
            .params(clustered_params())
            .workers(1)
            .build_indexed(serve_cfg(quantized));
        let reference =
            QueryEngine::new(rindex, &h, ServeMeasure::Cosine, clustered_params()).workers(1);
        let snap_only = reference.query(&queries, 10);
        for i in 0..extra.len() {
            reference.insert(Some(extra.row(i)), None);
        }
        let with_delta = reference.query(&queries, 10);
        let with_delta_dq = reference.query(&dqueries, 10);
        assert!(reference.compact());
        let compacted = reference.query(&queries, 10);
        let compacted_dq = reference.query(&dqueries, 10);
        // One sharded build; each (shards, workers) cell re-fences it.
        let (_, sbase) = StarsBuilder::new(&ds)
            .similarity(&CosineSim)
            .hash(&h)
            .params(clustered_params())
            .workers(1)
            .build_sharded(1, serve_cfg(quantized));
        for ns in [1usize, 2, 3, 8] {
            for workers in [1usize, 8] {
                let cell = format!("({tier}, {ns} shards, {workers} workers)");
                let eng = ShardedEngine::new(
                    sbase.resharded(ns),
                    &h,
                    ServeMeasure::Cosine,
                    clustered_params(),
                )
                .workers(workers);
                assert_eq!(eng.n_shards(), ns);
                assert_eq!(
                    eng.query(&queries, 10),
                    snap_only,
                    "snapshot-only answers diverged {cell}"
                );
                // Live delta: same inserts, same global ids.
                for i in 0..extra.len() {
                    assert_eq!(eng.insert(Some(extra.row(i)), None), 700 + i as u32);
                }
                assert_eq!(eng.num_pending(), 24);
                assert_eq!(
                    eng.query(&queries, 10),
                    with_delta,
                    "delta-path answers diverged {cell}"
                );
                assert_eq!(
                    eng.query(&dqueries, 10),
                    with_delta_dq,
                    "delta-point queries diverged {cell}"
                );
                // Incremental compaction: per-shard deltas reassemble into
                // the same epoch the reference's single buffer produced.
                let rep = eng.compact_report().expect("delta pending");
                assert_eq!(rep.mode, CompactionMode::Incremental);
                assert_eq!(rep.delta_points, 24);
                assert_eq!(eng.num_pending(), 0);
                assert_eq!(eng.num_indexed(), 724);
                assert_eq!(
                    eng.query(&queries, 10),
                    compacted,
                    "post-compaction answers diverged {cell}"
                );
                assert_eq!(
                    eng.query(&dqueries, 10),
                    compacted_dq,
                    "post-compaction delta-point queries diverged {cell}"
                );
            }
        }
    }
}

#[test]
fn fence_edge_cases_keep_bit_identity() {
    // Degenerate fences: more shards than points (some shards own zero
    // points), single-point shards, and inserts landing on empty shards —
    // answers must still match the single-shard engine bit for bit.
    let ds = synth::gaussian_mixture(5, 8, 2, 0.05, 9);
    let h = SimHash::new(8, 6, 3);
    let params = BuildParams::threshold_mode(Algorithm::LshStars)
        .sketches(4)
        .threshold(0.3);
    let (_, rindex) = StarsBuilder::new(&ds)
        .similarity(&CosineSim)
        .hash(&h)
        .params(params.clone())
        .build_indexed(serve_cfg(false));
    let reference = QueryEngine::new(rindex, &h, ServeMeasure::Cosine, params.clone()).workers(1);
    let queries = ds.subset(&[0, 1, 2, 3, 4]);
    let want = reference.query(&queries, 3);
    reference.insert(Some(ds.row(2)), None);
    let want_delta = reference.query(&queries, 3);
    for ns in [2usize, 5, 9] {
        let (_, sindex) = StarsBuilder::new(&ds)
            .similarity(&CosineSim)
            .hash(&h)
            .params(params.clone())
            .build_sharded(ns, serve_cfg(false));
        // Oversharded fences are monotone, cover all points, and contain
        // at least one empty shard when ns > n.
        let fence = sindex.fence().to_vec();
        assert_eq!(fence.len(), ns + 1);
        assert!(fence.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(*fence.last().unwrap(), 5);
        if ns > 5 {
            assert!(
                fence.windows(2).any(|w| w[0] == w[1]),
                "no empty shard in the {ns}-way fence over 5 points"
            );
        }
        let eng =
            ShardedEngine::new(sindex, &h, ServeMeasure::Cosine, params.clone()).workers(2);
        assert_eq!(eng.query(&queries, 3), want, "{ns}-way snapshot diverged");
        // Empty-shard telemetry stays well-formed.
        for s in 0..ns {
            let st = eng.shard_stats(s);
            assert!(st.points <= 5);
        }
        // The insert's owner shard is gid % ns — possibly a shard that
        // owns no snapshot points — and must still be scored.
        assert_eq!(eng.insert(Some(ds.row(2)), None), 5);
        assert_eq!(eng.query(&queries, 3), want_delta, "{ns}-way delta diverged");
    }
}

#[test]
fn fence_for_tiles_the_id_space() {
    let f = fence_for(10, 3);
    assert_eq!(f, vec![0, 3, 6, 10]);
    assert_eq!(fence_for(0, 4), vec![0, 0, 0, 0, 0]);
    assert_eq!(fence_for(7, 1), vec![0, 7]);
    // Balanced within one point.
    let f = fence_for(1003, 7);
    let sizes: Vec<u64> = f.windows(2).map(|w| w[1] - w[0]).collect();
    assert_eq!(sizes.iter().sum::<u64>(), 1003);
    assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
}

#[test]
fn resharding_preserves_the_snapshot_and_engine_answers() {
    // ShardedIndex::resharded re-fences the same Arc'd snapshot — the
    // bench sweeps shard counts off one build this way, so it must be
    // answer-preserving too.
    let ds = synth::gaussian_mixture(300, 16, 6, 0.08, 41);
    let h = SimHash::new(16, 8, 7);
    let params = clustered_params();
    let (_, index) = StarsBuilder::new(&ds)
        .similarity(&CosineSim)
        .hash(&h)
        .params(params.clone())
        .build_indexed(serve_cfg(false));
    let base = ShardedIndex::new(index, 3);
    assert_eq!(base.n_shards(), 3);
    let queries = ds.subset(&[0, 50, 299]);
    let mut baseline: Option<Vec<Vec<(u32, f32)>>> = None;
    for ns in [1usize, 4, 7] {
        let re = base.resharded(ns);
        assert_eq!(re.n_shards(), ns);
        assert_eq!(re.snapshot().len(), 300);
        let eng = ShardedEngine::new(re, &h, ServeMeasure::Cosine, params.clone()).workers(2);
        let got = eng.query(&queries, 5);
        match &baseline {
            None => baseline = Some(got),
            Some(b) => assert_eq!(b, &got, "resharded({ns}) diverged"),
        }
    }
}

#[test]
fn sorting_builds_serve_sharded_through_the_resketch_fallback() {
    // SortingLshStars shares no routing keys with the snapshot export
    // (sorted-window builds bucket differently), so build_sharded goes
    // through the documented re-sketch fallback — and must still serve
    // bit-identically to the single-shard engine built the same way.
    let ds = synth::gaussian_mixture(400, 16, 8, 0.08, 21);
    let h = SimHash::new(16, 8, 7);
    let params = BuildParams::knn_mode(Algorithm::SortingLshStars).sketches(6);
    let (_, rindex) = StarsBuilder::new(&ds)
        .similarity(&CosineSim)
        .hash(&h)
        .params(params.clone())
        .build_indexed(serve_cfg(false));
    let reference = QueryEngine::new(rindex, &h, ServeMeasure::Cosine, params.clone()).workers(1);
    let queries = ds.subset(&[0, 13, 77, 200, 399]);
    let want = reference.query(&queries, 5);
    // Deliberately pass a config with the default candidate cap:
    // build_sharded must force it to 0 (matching serve_cfg's explicit 0
    // above) before exporting.
    let capped = ServeConfig::default().route_reps(8).compact_limit(0);
    let (_, sindex) = StarsBuilder::new(&ds)
        .similarity(&CosineSim)
        .hash(&h)
        .params(params.clone())
        .build_sharded(3, capped);
    let snap: std::sync::Arc<StarIndex<'_>> = sindex.snapshot();
    assert_eq!(
        snap.config().max_candidates,
        0,
        "build_sharded must force the uncapped candidate walk"
    );
    let eng = ShardedEngine::new(sindex, &h, ServeMeasure::Cosine, params).workers(4);
    assert_eq!(
        eng.query(&queries, 5),
        want,
        "sorting-build sharded answers diverged from single-shard"
    );
}
