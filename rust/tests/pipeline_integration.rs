//! End-to-end pipeline integration: build graphs with every algorithm on a
//! structured dataset and verify the paper's qualitative claims hold —
//! Stars uses far fewer comparisons, recall stays high in two hops, and
//! downstream clustering quality is preserved.

use stars::clustering::{affinity_cluster_to_k, v_measure};
use stars::data::synth;
use stars::eval::recall::{knn_recall, sample_queries, threshold_recall};
use stars::graph::Csr;
use stars::lsh::{MixtureHash, SimHash, WeightedMinHash};
use stars::sim::{CosineSim, CountingSim, MixtureSim, WeightedJaccardSim};
use stars::stars::{allpair, Algorithm, BuildParams, StarsBuilder};

#[test]
fn lsh_stars_vs_lsh_comparisons_and_recall() {
    // 20 modes of 150 points each so LSH buckets exceed the 2s stars
    // fallback threshold and star scoring actually engages.
    let ds = synth::gaussian_mixture(3000, 100, 20, 0.1, 5);
    let family = SimHash::new(100, 8, 3);
    let cluster = stars::ampc::Cluster::new(4);
    let truth = allpair::exact_threshold_neighbors(&ds, &CosineSim, 0.5, &cluster);
    let queries = sample_queries(ds.len(), 300, 17);

    let run = |algo: Algorithm| {
        let sim = CountingSim::new(CosineSim);
        let out = StarsBuilder::new(&ds)
            .similarity(&sim)
            .hash(&family)
            .params(BuildParams::threshold_mode(algo).sketches(50).leaders(5))
            .workers(4)
            .build();
        let csr = Csr::new(&out.graph);
        let rec = threshold_recall(&csr, &truth, &queries, 0.5, 0.495);
        (out.report.comparisons, rec)
    };

    let (c_stars, rec_stars) = run(Algorithm::LshStars);
    let (c_lsh, rec_lsh) = run(Algorithm::Lsh);

    // Figure 1's claim: ~10x fewer comparisons (leaders=25 vs whole-bucket
    // all-pairs). Tolerate anything >= 2x on this small instance.
    assert!(
        c_stars * 2 <= c_lsh,
        "stars {c_stars} comparisons not well below lsh {c_lsh}"
    );
    // Figure 2's claim: two-hop recall of Stars comparable to one-hop recall
    // of non-Stars.
    assert!(
        rec_stars.two_hop_relaxed > 0.6,
        "stars 2-hop recall too low: {:?}",
        rec_stars
    );
    assert!(
        rec_stars.two_hop_relaxed > rec_lsh.one_hop - 0.15,
        "stars 2-hop {:?} << lsh 1-hop {:?}",
        rec_stars,
        rec_lsh
    );
}

#[test]
fn sortinglsh_stars_knn_recall() {
    let ds = synth::gaussian_mixture(2000, 100, 50, 0.1, 6);
    let family = SimHash::new(100, 30, 4);
    let cluster = stars::ampc::Cluster::new(4);
    let k = 20;
    let truth = allpair::exact_knn(&ds, &CosineSim, k, &cluster);
    let queries = sample_queries(ds.len(), 200, 23);

    let run = |algo: Algorithm, r: usize| {
        let sim = CountingSim::new(CosineSim);
        let out = StarsBuilder::new(&ds)
            .similarity(&sim)
            .hash(&family)
            .params(BuildParams::knn_mode(algo).sketches(r).window(100))
            .workers(4)
            .build();
        let csr = Csr::new(&out.graph);
        (
            out.report.comparisons,
            knn_recall(&ds, &CosineSim, &csr, &truth, &queries, k, 0.99),
        )
    };

    let (c_stars, rec_stars) = run(Algorithm::SortingLshStars, 25);
    let (c_np, rec_np) = run(Algorithm::SortingLsh, 25);

    assert!(c_stars < c_np, "stars {c_stars} !< non-stars {c_np}");
    assert!(
        rec_stars.two_hop > 0.7,
        "stars 2-hop knn recall {:?}",
        rec_stars
    );
    assert!(rec_np.one_hop > 0.5, "baseline sanity: {:?}", rec_np);
    assert!(
        rec_stars.two_hop_relaxed >= rec_stars.two_hop - 1e-9,
        "relaxed must not decrease"
    );
}

#[test]
fn clustering_quality_preserved_with_stars() {
    // Figure 4's claim: graphs built with ~10x fewer comparisons lose almost
    // no downstream V-Measure.
    let ds = synth::gaussian_mixture(3000, 64, 10, 0.12, 7);
    let family = SimHash::new(64, 10, 8);
    let run = |algo: Algorithm| {
        let sim = CosineSim;
        let out = StarsBuilder::new(&ds)
            .similarity(&sim)
            .hash(&family)
            .params(BuildParams::threshold_mode(algo).sketches(60).threshold(0.4))
            .workers(4)
            .build();
        let level = affinity_cluster_to_k(&out.graph.filter_weight(0.4), 10);
        v_measure(&level.labels, &ds.labels).v
    };
    let v_stars = run(Algorithm::LshStars);
    let v_lsh = run(Algorithm::Lsh);
    assert!(v_stars > 0.5, "stars clustering degenerate: {v_stars}");
    assert!(
        v_stars > v_lsh - 0.1,
        "stars V-Measure {v_stars} far below non-stars {v_lsh}"
    );
}

#[test]
fn weighted_jaccard_pipeline_on_zipf_sets() {
    let ds = synth::zipf_sets(1500, &synth::ZipfSetsParams::default(), 8);
    let family = WeightedMinHash::new(3, 21);
    let sim = CountingSim::new(WeightedJaccardSim);
    let out = StarsBuilder::new(&ds)
        .similarity(&sim)
        .hash(&family)
        .params(
            BuildParams::threshold_mode(Algorithm::LshStars)
                .sketches(30)
                .threshold(0.1),
        )
        .workers(4)
        .build();
    assert!(out.graph.num_edges() > 0, "no edges on the sets dataset");
    // Edges must dominantly connect same-topic documents.
    let same = out
        .graph
        .edges()
        .iter()
        .filter(|e| ds.labels[e.u as usize] == ds.labels[e.v as usize])
        .count();
    assert!(
        same * 10 > out.graph.num_edges() * 8,
        "only {same}/{} edges within topics",
        out.graph.num_edges()
    );
}

#[test]
fn mixture_hash_pipeline_on_products() {
    let ds = synth::products(1500, &synth::ProductsParams::default(), 9);
    let family = MixtureHash::new(ds.dim(), 12, 31);
    let sim = MixtureSim { alpha: 0.5 };
    let out = StarsBuilder::new(&ds)
        .similarity(&sim)
        .hash(&family)
        .params(
            BuildParams::threshold_mode(Algorithm::LshStars)
                .sketches(40)
                .threshold(0.35),
        )
        .workers(4)
        .build();
    assert!(out.graph.num_edges() > 0);
    let same = out
        .graph
        .edges()
        .iter()
        .filter(|e| ds.labels[e.u as usize] == ds.labels[e.v as usize])
        .count();
    assert!(
        same * 10 > out.graph.num_edges() * 7,
        "mixture edges not class-aligned: {same}/{}",
        out.graph.num_edges()
    );
}

#[test]
fn total_time_tracks_worker_sum() {
    let ds = synth::gaussian_mixture(2000, 64, 20, 0.1, 10);
    let family = SimHash::new(64, 10, 2);
    let out = StarsBuilder::new(&ds)
        .similarity(&CosineSim)
        .hash(&family)
        .params(BuildParams::threshold_mode(Algorithm::LshStars).sketches(16))
        .workers(4)
        .build();
    // Both must be positive; on a multi-core host total (sum of busy)
    // exceeds real (wall), but merge/finalize work outside the worker spans
    // is uncharged, so only require the bulk of wall time to be accounted.
    assert!(out.report.total_time > 0.0);
    assert!(out.report.real_time > 0.0);
    assert!(out.report.total_time >= out.report.real_time * 0.3);
}
