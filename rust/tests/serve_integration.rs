//! Serving-path acceptance tests: recall parity against brute force,
//! streaming insert-then-query correctness across compaction, and the
//! worker-count invariance contract of the batched query executor.
//!
//! The `quantized_*` tests gate the int8 first-pass tier: its recall must
//! stay within 2% of the f32 path on the clustered fixture (the documented
//! parity *relaxation* — see ARCHITECTURE.md "Quantized scoring tier"),
//! while the quantized path itself stays worker-count-invariant like every
//! other serve path. `scripts/ci.sh` re-runs them under STARS_SIMD=scalar.

use stars::data::synth;
use stars::lsh::{SimHash, WeightedMinHash};
use stars::serve::{
    brute_force_topk, recall_against, Admission, AdmissionConfig, CompactionMode, FrontDoor,
    QueryEngine, ServeConfig, ServeMeasure, ShardedEngine, ShedReason,
};
use stars::sim::{CosineSim, WeightedJaccardSim};
use stars::stars::{Algorithm, BuildParams, StarsBuilder};

fn clustered_params() -> BuildParams {
    BuildParams::threshold_mode(Algorithm::LshStars)
        .sketches(10)
        .threshold(0.5)
}

/// Build the synthetic clustered fixture: 2000 points, 20 well-separated
/// Gaussian modes, and an engine over its star graph.
fn build_cosine_engine(
    h: &SimHash,
    workers: usize,
    compact_limit: usize,
) -> (stars::data::Dataset, QueryEngine<'_>) {
    let ds = synth::gaussian_mixture(2000, 16, 20, 0.08, 33);
    let params = clustered_params();
    let (_, index) = StarsBuilder::new(&ds)
        .similarity(&CosineSim)
        .hash(h)
        .params(params.clone())
        .workers(workers)
        .build_indexed(
            ServeConfig::default()
                .route_reps(8)
                .compact_limit(compact_limit),
        );
    let engine = QueryEngine::new(index, h, ServeMeasure::Cosine, params).workers(workers);
    (ds, engine)
}

/// [`build_cosine_engine`] with the quantized first-pass tier enabled.
fn build_quantized_engine(
    h: &SimHash,
    workers: usize,
    rescore_factor: usize,
) -> (stars::data::Dataset, QueryEngine<'_>) {
    let ds = synth::gaussian_mixture(2000, 16, 20, 0.08, 33);
    let params = clustered_params();
    let (_, index) = StarsBuilder::new(&ds)
        .similarity(&CosineSim)
        .hash(h)
        .params(params.clone())
        .workers(workers)
        .build_indexed(
            ServeConfig::default()
                .route_reps(8)
                .compact_limit(0)
                .quantized(rescore_factor),
        );
    let engine = QueryEngine::new(index, h, ServeMeasure::Cosine, params).workers(workers);
    (ds, engine)
}

#[test]
fn recall_at_10_beats_point_nine_vs_brute_force() {
    let h = SimHash::new(16, 8, 7);
    let (ds, engine) = build_cosine_engine(&h, 4, 0);
    let qids: Vec<u32> = (0..2000u32).step_by(40).collect(); // 50 queries
    let queries = ds.subset(&qids);
    let got = engine.query(&queries, 10);
    let truth = brute_force_topk(&ds, &queries, ServeMeasure::Cosine, 10, 4);
    let recall = truth
        .iter()
        .zip(got.iter())
        .map(|(t, g)| recall_against(t, g))
        .sum::<f64>()
        / qids.len() as f64;
    assert!(recall >= 0.9, "recall@10 = {recall:.3} < 0.9");
    // Engine scores are true similarities: spot-check against the measure.
    for (qi, res) in got.iter().enumerate() {
        for &(id, w) in res.iter().take(3) {
            let want = stars::sim::cosine(queries.row(qi), ds.row(id as usize));
            assert!((w - want).abs() < 1e-5, "score drift on ({qi}, {id})");
        }
    }
}

#[test]
fn quantized_recall_tracks_the_f32_path() {
    // The documented parity relaxation: quantized recall@10 must hold at
    // least 98% of the f32 path's recall on the clustered fixture (both
    // measured against exact brute force over the whole dataset).
    let h = SimHash::new(16, 8, 7);
    let qids: Vec<u32> = (0..2000u32).step_by(40).collect(); // 50 queries
    let (ds, exact) = build_cosine_engine(&h, 4, 0);
    let queries = ds.subset(&qids);
    let truth = brute_force_topk(&ds, &queries, ServeMeasure::Cosine, 10, 4);
    let recall_of = |got: &[Vec<(u32, f32)>]| {
        truth
            .iter()
            .zip(got.iter())
            .map(|(t, g)| recall_against(t, g))
            .sum::<f64>()
            / qids.len() as f64
    };
    let recall_f32 = recall_of(&exact.query(&queries, 10));
    drop(exact);
    let (_, quant) = build_quantized_engine(&h, 4, 4);
    assert!(quant.snapshot().quant().is_some(), "SQ8 table missing");
    let got_q = quant.query(&queries, 10);
    let recall_q = recall_of(&got_q);
    assert!(
        recall_q >= 0.98 * recall_f32,
        "quantized recall@10 = {recall_q:.3} < 0.98 · {recall_f32:.3}"
    );
    // Survivor scores are exact (the rescore runs the f32 kernels): every
    // returned score must equal the true similarity, not an estimate.
    for (qi, res) in got_q.iter().enumerate() {
        for &(id, w) in res.iter().take(3) {
            let want = stars::sim::cosine(queries.row(qi), ds.row(id as usize));
            assert!((w - want).abs() < 1e-5, "estimated score leaked ({qi}, {id})");
        }
    }
    // Snapshot telemetry shows the ~4× first-pass storage reduction.
    let stats = quant.snapshot().stats();
    assert!(stats.quantized);
    assert_eq!(stats.bytes_per_row, 16 + 4);
    assert_eq!(stats.quant_bytes, 2000 * (16 + 4));
}

#[test]
fn quantized_results_are_worker_count_invariant() {
    // The quantized path inherits the determinism contract: the int8 first
    // pass is integer-exact and per-query, so results are bit-identical
    // for every worker count — snapshot-only and with a live delta.
    let h = SimHash::new(16, 8, 7);
    let qids: Vec<u32> = (0..2000u32).step_by(101).collect();
    let (ds, engine1) = build_quantized_engine(&h, 1, 4);
    let queries = ds.subset(&qids);
    let baseline = engine1.query(&queries, 10);
    drop(engine1);
    for workers in [3usize, 8] {
        let (_, engine) = build_quantized_engine(&h, workers, 4);
        assert_eq!(
            engine.query(&queries, 10),
            baseline,
            "quantized snapshot results differ between 1 and {workers} workers"
        );
        engine.insert(Some(ds.row(5)), None);
        let (_, e1) = build_quantized_engine(&h, 1, 4);
        e1.insert(Some(ds.row(5)), None);
        assert_eq!(
            engine.query(&queries, 10),
            e1.query(&queries, 10),
            "quantized delta-path results differ between 1 and {workers} workers"
        );
    }
}

#[test]
fn query_batches_are_worker_count_invariant() {
    let h = SimHash::new(16, 8, 7);
    let qids: Vec<u32> = (0..2000u32).step_by(101).collect();
    let (ds, engine1) = build_cosine_engine(&h, 1, 0);
    let queries = ds.subset(&qids);
    let baseline = engine1.query(&queries, 10);
    drop(engine1);
    for workers in [3usize, 8] {
        let (_, engine) = build_cosine_engine(&h, workers, 0);
        // Pure-snapshot path: bit-identical to the single-worker baseline.
        assert_eq!(
            engine.query(&queries, 10),
            baseline,
            "snapshot results differ between 1 and {workers} workers"
        );
        // Delta path: insert the same point into a fresh single-worker
        // engine and this one — still bit-identical.
        engine.insert(Some(ds.row(5)), None);
        let (_, e1) = build_cosine_engine(&h, 1, 0);
        e1.insert(Some(ds.row(5)), None);
        assert_eq!(
            engine.query(&queries, 10),
            e1.query(&queries, 10),
            "delta-path results differ between 1 and {workers} workers"
        );
    }
}

#[test]
fn delta_insert_then_query_then_compact_keeps_ids() {
    let h = SimHash::new(16, 8, 7);
    let (ds, engine) = build_cosine_engine(&h, 2, 0);
    let n = ds.len() as u32;
    // Insert an exact duplicate of point 42: it must be queryable
    // immediately, tie-broken after the original (ascending id).
    let id = engine.insert(Some(ds.row(42)), None);
    assert_eq!(id, n);
    assert_eq!(engine.num_pending(), 1);
    let queries = ds.subset(&[42]);
    let res = engine.query(&queries, 5);
    assert_eq!(res[0][0].0, 42, "original not first");
    assert_eq!(res[0][1].0, n, "delta duplicate not second");
    assert!((res[0][1].1 - 1.0).abs() < 1e-5);
    // Compact: the delta folds into a fresh epoch, ids unchanged.
    assert!(engine.compact());
    assert!(!engine.compact(), "second compact had nothing to do");
    assert_eq!(engine.num_pending(), 0);
    assert_eq!(engine.num_indexed(), n as usize + 1);
    let res = engine.query(&queries, 5);
    assert_eq!(res[0][0].0, 42);
    assert_eq!(res[0][1].0, n, "compacted point lost from the index path");
    assert!((res[0][1].1 - 1.0).abs() < 1e-5);
}

#[test]
fn auto_compaction_triggers_at_the_limit() {
    let h = SimHash::new(16, 8, 7);
    let (ds, engine) = build_cosine_engine(&h, 2, 3);
    let before = engine.num_indexed();
    engine.insert(Some(ds.row(0)), None);
    engine.insert(Some(ds.row(1)), None);
    assert_eq!(engine.num_pending(), 2);
    engine.insert(Some(ds.row(2)), None);
    assert_eq!(engine.num_pending(), 0, "limit did not trigger compaction");
    assert_eq!(engine.num_indexed(), before + 3);
}

/// Fixture for the compaction-equivalence tests: a configuration under
/// which a full rebuild's randomized machinery never engages, so the
/// incremental path must reproduce it bit for bit —
/// * `Algorithm::Lsh`: every bucket is all-pairs scored (no leader draws),
/// * `max_bucket` huge: no random sub-bucket splits,
/// * `route_leaders` ≥ any bucket size: the router retains every member,
/// * `route_reps == sketches`: routing covers every build repetition.
fn equivalence_engine(
    h: &SimHash,
    workers: usize,
    degree_cap: usize,
    mode: CompactionMode,
) -> (stars::data::Dataset, QueryEngine<'_>) {
    let ds = synth::gaussian_mixture(600, 16, 12, 0.08, 51);
    let params = BuildParams::threshold_mode(Algorithm::Lsh)
        .sketches(6)
        .threshold(0.35)
        .max_bucket(1_000_000)
        .degree_cap(degree_cap);
    let cfg = ServeConfig::default()
        .route_reps(6)
        .route_leaders(4096)
        .probe_entries(8)
        .compact_limit(0)
        .compaction(mode);
    let (_, index) = StarsBuilder::new(&ds)
        .similarity(&CosineSim)
        .hash(h)
        .params(params.clone())
        .workers(workers)
        .build_indexed(cfg);
    let engine = QueryEngine::new(index, h, ServeMeasure::Cosine, params).workers(workers);
    (ds, engine)
}

#[test]
fn incremental_compaction_is_bit_identical_to_full_rebuild() {
    let h = SimHash::new(16, 9, 13);
    let extra = synth::gaussian_mixture(64, 16, 12, 0.08, 52);
    let qids: Vec<u32> = (0..664u32).step_by(13).collect(); // old + delta points
    let mut baseline: Option<Vec<Vec<(u32, f32)>>> = None;
    for workers in [1usize, 4] {
        for degree_cap in [0usize, 48] {
            let (_, inc) = equivalence_engine(&h, workers, degree_cap, CompactionMode::Incremental);
            let (_, full) = equivalence_engine(&h, workers, degree_cap, CompactionMode::Full);
            for i in 0..extra.len() {
                inc.insert(Some(extra.row(i)), None);
                full.insert(Some(extra.row(i)), None);
            }
            let ri = inc.compact_report().expect("incremental had a delta");
            let rf = full.compact_report().expect("full had a delta");
            assert_eq!(ri.mode, CompactionMode::Incremental);
            assert_eq!(rf.mode, CompactionMode::Full);
            assert!(ri.affected_buckets > 0);
            assert!(
                ri.candidates_scored < rf.candidates_scored,
                "incremental ({}) did not score less than the rebuild ({})",
                ri.candidates_scored,
                rf.candidates_scored
            );
            // CSR edges: bit-identical adjacency, node by node.
            let (si, sf) = (inc.snapshot(), full.snapshot());
            assert_eq!(si.len(), 664);
            assert_eq!(
                si.csr().num_edges(),
                sf.csr().num_edges(),
                "edge count differs (workers={workers}, cap={degree_cap})"
            );
            for u in 0..si.len() as u32 {
                let a: Vec<(u32, f32)> = si.csr().neighbors(u).collect();
                let b: Vec<(u32, f32)> = sf.csr().neighbors(u).collect();
                assert_eq!(a, b, "adjacency differs at node {u} (workers={workers}, cap={degree_cap})");
            }
            // Query top-k: bit-identical over old and compacted points,
            // and identical across worker counts (cap=0 arm as baseline).
            let queries = si.dataset().subset(&qids);
            let got_inc = inc.query(&queries, 10);
            let got_full = full.query(&queries, 10);
            assert_eq!(
                got_inc, got_full,
                "top-k differs (workers={workers}, cap={degree_cap})"
            );
            if degree_cap == 0 {
                if let Some(b) = &baseline {
                    assert_eq!(
                        &got_inc, b,
                        "incremental compaction not worker-invariant ({workers} workers)"
                    );
                } else {
                    baseline = Some(got_inc);
                }
            }
        }
    }
}

#[test]
fn repeated_incremental_compactions_stay_consistent() {
    // Sustained insert traffic: several insert→compact rounds through the
    // incremental path keep global ids stable and every point queryable.
    let h = SimHash::new(16, 9, 13);
    let (_, engine) = equivalence_engine(&h, 2, 48, CompactionMode::Incremental);
    let extra = synth::gaussian_mixture(30, 16, 12, 0.08, 77);
    let mut next_id = 600u32;
    for round in 0..3 {
        for i in (round * 10)..(round * 10 + 10) {
            let id = engine.insert(Some(extra.row(i)), None);
            assert_eq!(id, next_id, "global ids must be stable across epochs");
            next_id += 1;
        }
        assert!(engine.compact(), "round {round} had a delta to absorb");
        assert_eq!(engine.num_pending(), 0);
        assert_eq!(engine.num_indexed(), 600 + (round + 1) * 10);
    }
    // Every absorbed point is self-retrievable through the graph path.
    let snap = engine.snapshot();
    let delta_ids: Vec<u32> = (600..630).collect();
    let queries = snap.dataset().subset(&delta_ids);
    let res = engine.query(&queries, 3);
    for (qi, &id) in delta_ids.iter().enumerate() {
        assert_eq!(res[qi][0].0, id, "absorbed point {id} not its own top-1");
        assert!((res[qi][0].1 - 1.0).abs() < 1e-5);
    }
}

#[test]
fn set_family_incremental_compaction_roundtrip() {
    // Weighted-Jaccard over Zipf sets through the incremental path: delta
    // sets are sketched through the snapshot's cached CWS tables (with the
    // out-of-vocab fallback for unseen tokens) and must come back as their
    // own nearest neighbors after the fold.
    let sets = synth::zipf_sets(400, &synth::ZipfSetsParams::default(), 29);
    let fresh = synth::zipf_sets(12, &synth::ZipfSetsParams::default(), 31);
    let h = WeightedMinHash::new(3, 11);
    let params = BuildParams::threshold_mode(Algorithm::LshStars)
        .sketches(6)
        .threshold(0.1);
    let (_, index) = StarsBuilder::new(&sets)
        .similarity(&WeightedJaccardSim)
        .hash(&h)
        .params(params.clone())
        .workers(2)
        .build_indexed(
            ServeConfig::default()
                .route_reps(6)
                .route_leaders(16)
                .compact_limit(0)
                .compaction(CompactionMode::Incremental),
        );
    let engine = QueryEngine::new(index, &h, ServeMeasure::WeightedJaccard, params).workers(2);
    for i in 0..fresh.len() {
        assert_eq!(engine.insert(None, Some(fresh.set(i).clone())), (400 + i) as u32);
    }
    let rep = engine.compact_report().expect("delta pending");
    assert_eq!(rep.mode, CompactionMode::Incremental);
    assert_eq!(rep.delta_points, 12);
    assert_eq!(engine.num_indexed(), 412);
    let snap = engine.snapshot();
    let delta_ids: Vec<u32> = (400..412).collect();
    let res = engine.query(&snap.dataset().subset(&delta_ids), 3);
    for (qi, &id) in delta_ids.iter().enumerate() {
        assert_eq!(res[qi][0].0, id, "absorbed set {id} not its own top-1");
        assert!((res[qi][0].1 - 1.0).abs() < 1e-5);
    }
}

#[test]
fn tenant_caps_shed_the_hot_tenant_and_spare_the_cold_one() {
    // Per-tenant QPS token buckets at the front door: a hot tenant burns
    // its burst and is shed with ShedReason::TenantCap; a cold tenant's
    // untouched bucket admits it, and its results are bit-identical to the
    // door-less engine. Refill at 0.001 qps is negligible over the test's
    // lifetime, so the counts are deterministic.
    let h = SimHash::new(16, 8, 7);
    let (ds, engine) = build_cosine_engine(&h, 2, 0);
    let queries = ds.subset(&[3, 44, 199]);
    let door = FrontDoor::new(
        &engine,
        AdmissionConfig::default()
            .queue_limit(8)
            .tenant_qps(0.001)
            .tenant_burst(2),
    );
    let want = engine.query(&queries, 5);
    for round in 0..2 {
        match door.query_for(7, &queries, 5) {
            Admission::Served(got) => assert_eq!(got, want, "hot round {round}"),
            other => panic!("hot tenant refused inside its burst: {other:?}"),
        }
    }
    for round in 0..3 {
        match door.query_for(7, &queries, 5) {
            Admission::Shed(ShedReason::TenantCap) => {}
            other => panic!("hot tenant not capped (round {round}): {other:?}"),
        }
    }
    match door.query_for(13, &queries, 5) {
        Admission::Served(got) => assert_eq!(got, want, "cold tenant results drifted"),
        other => panic!("cold tenant starved by the hot one: {other:?}"),
    }
    let stats = door.stats();
    assert_eq!(stats.tenant_sheds, 3);
    assert_eq!(stats.admitted, 3);
    assert_eq!(stats.queue_sheds, 0);
    assert_eq!(stats.deadline_sheds, 0);
    assert!(stats.shed() >= 3);
    // Untenanted traffic (plain query) bypasses the buckets entirely.
    assert!(!door.query(&queries, 5).is_shed());
}

#[test]
fn merge_ties_straddling_a_fence_keep_the_total_order() {
    // Two bit-identical rows placed on opposite sides of the 2-shard fence
    // produce bit-equal scores from different shards; the gather's total
    // order (score desc, id asc) must rank them exactly like the single
    // engine's heap does — ascending id — for any worker count.
    let base = synth::gaussian_mixture(100, 16, 5, 0.08, 61);
    let mut idx: Vec<u32> = (0..100).collect();
    idx[60] = 10; // rows 10 and 60 are now identical, fence at 50 splits them
    let ds = base.subset(&idx);
    let h = SimHash::new(16, 8, 7);
    let params = BuildParams::threshold_mode(Algorithm::LshStars)
        .sketches(8)
        .threshold(0.3);
    let cfg = || {
        ServeConfig::default()
            .route_reps(8)
            .compact_limit(0)
            .max_candidates(0)
    };
    let (_, rindex) = StarsBuilder::new(&ds)
        .similarity(&CosineSim)
        .hash(&h)
        .params(params.clone())
        .build_indexed(cfg());
    let single = QueryEngine::new(rindex, &h, ServeMeasure::Cosine, params.clone()).workers(1);
    let queries = ds.subset(&[10]);
    let want = single.query(&queries, 5);
    // The duplicate pair ties at similarity 1.0 and must come back in
    // ascending-id order from the single engine already.
    assert_eq!(want[0][0].0, 10, "original not first");
    assert_eq!(want[0][1].0, 60, "duplicate not second");
    assert_eq!(
        want[0][0].1.to_bits(),
        want[0][1].1.to_bits(),
        "duplicate rows must score bit-equal"
    );
    for workers in [1usize, 4] {
        let (_, sindex) = StarsBuilder::new(&ds)
            .similarity(&CosineSim)
            .hash(&h)
            .params(params.clone())
            .build_sharded(2, cfg());
        assert_eq!(sindex.fence(), &[0, 50, 100]);
        let eng =
            ShardedEngine::new(sindex, &h, ServeMeasure::Cosine, params.clone()).workers(workers);
        assert_eq!(
            eng.query(&queries, 5),
            want,
            "fence-straddling tie broke the total order ({workers} workers)"
        );
    }
}

#[test]
fn set_measure_serving_self_retrieval() {
    // Weighted-Jaccard over Zipf token sets: the set-family serving path
    // (per-token CWS tables on the query side, hash-expanded query set in
    // the scoring kernel).
    let sets = synth::zipf_sets(500, &synth::ZipfSetsParams::default(), 29);
    let h = WeightedMinHash::new(3, 11);
    let params = BuildParams::threshold_mode(Algorithm::LshStars)
        .sketches(8)
        .threshold(0.1);
    let (_, index) = StarsBuilder::new(&sets)
        .similarity(&WeightedJaccardSim)
        .hash(&h)
        .params(params.clone())
        .workers(2)
        .build_indexed(ServeConfig::default().route_reps(6));
    let engine = QueryEngine::new(index, &h, ServeMeasure::WeightedJaccard, params).workers(2);
    let qids = [0u32, 99, 250, 499];
    let queries = sets.subset(&qids);
    let res = engine.query(&queries, 5);
    for (qi, &p) in qids.iter().enumerate() {
        assert!(!res[qi].is_empty(), "query {p} found nothing");
        assert_eq!(res[qi][0].0, p, "self not top-1 for set point {p}");
        assert!((res[qi][0].1 - 1.0).abs() < 1e-5);
    }
    // Streaming a new set point works end to end.
    let id = engine.insert(None, Some(sets.set(7).clone()));
    assert_eq!(id, 500);
    let res = engine.query(&sets.subset(&[7]), 3);
    assert!(res[0].iter().any(|&(i, _)| i == 500), "delta set not found");
}
