//! Serving-path acceptance tests: recall parity against brute force,
//! streaming insert-then-query correctness across compaction, and the
//! worker-count invariance contract of the batched query executor.

use stars::data::synth;
use stars::lsh::{SimHash, WeightedMinHash};
use stars::serve::{brute_force_topk, recall_against, QueryEngine, ServeConfig, ServeMeasure};
use stars::sim::{CosineSim, WeightedJaccardSim};
use stars::stars::{Algorithm, BuildParams, StarsBuilder};

fn clustered_params() -> BuildParams {
    BuildParams::threshold_mode(Algorithm::LshStars)
        .sketches(10)
        .threshold(0.5)
}

/// Build the synthetic clustered fixture: 2000 points, 20 well-separated
/// Gaussian modes, and an engine over its star graph.
fn build_cosine_engine(
    h: &SimHash,
    workers: usize,
    compact_limit: usize,
) -> (stars::data::Dataset, QueryEngine<'_>) {
    let ds = synth::gaussian_mixture(2000, 16, 20, 0.08, 33);
    let params = clustered_params();
    let (_, index) = StarsBuilder::new(&ds)
        .similarity(&CosineSim)
        .hash(h)
        .params(params.clone())
        .workers(workers)
        .build_indexed(
            ServeConfig::default()
                .route_reps(8)
                .compact_limit(compact_limit),
        );
    let engine = QueryEngine::new(index, h, ServeMeasure::Cosine, params).workers(workers);
    (ds, engine)
}

#[test]
fn recall_at_10_beats_point_nine_vs_brute_force() {
    let h = SimHash::new(16, 8, 7);
    let (ds, engine) = build_cosine_engine(&h, 4, 0);
    let qids: Vec<u32> = (0..2000u32).step_by(40).collect(); // 50 queries
    let queries = ds.subset(&qids);
    let got = engine.query(&queries, 10);
    let truth = brute_force_topk(&ds, &queries, ServeMeasure::Cosine, 10, 4);
    let recall = truth
        .iter()
        .zip(got.iter())
        .map(|(t, g)| recall_against(t, g))
        .sum::<f64>()
        / qids.len() as f64;
    assert!(recall >= 0.9, "recall@10 = {recall:.3} < 0.9");
    // Engine scores are true similarities: spot-check against the measure.
    for (qi, res) in got.iter().enumerate() {
        for &(id, w) in res.iter().take(3) {
            let want = stars::sim::cosine(queries.row(qi), ds.row(id as usize));
            assert!((w - want).abs() < 1e-5, "score drift on ({qi}, {id})");
        }
    }
}

#[test]
fn query_batches_are_worker_count_invariant() {
    let h = SimHash::new(16, 8, 7);
    let qids: Vec<u32> = (0..2000u32).step_by(101).collect();
    let (ds, engine1) = build_cosine_engine(&h, 1, 0);
    let queries = ds.subset(&qids);
    let baseline = engine1.query(&queries, 10);
    drop(engine1);
    for workers in [3usize, 8] {
        let (_, engine) = build_cosine_engine(&h, workers, 0);
        // Pure-snapshot path: bit-identical to the single-worker baseline.
        assert_eq!(
            engine.query(&queries, 10),
            baseline,
            "snapshot results differ between 1 and {workers} workers"
        );
        // Delta path: insert the same point into a fresh single-worker
        // engine and this one — still bit-identical.
        engine.insert(Some(ds.row(5)), None);
        let (_, e1) = build_cosine_engine(&h, 1, 0);
        e1.insert(Some(ds.row(5)), None);
        assert_eq!(
            engine.query(&queries, 10),
            e1.query(&queries, 10),
            "delta-path results differ between 1 and {workers} workers"
        );
    }
}

#[test]
fn delta_insert_then_query_then_compact_keeps_ids() {
    let h = SimHash::new(16, 8, 7);
    let (ds, engine) = build_cosine_engine(&h, 2, 0);
    let n = ds.len() as u32;
    // Insert an exact duplicate of point 42: it must be queryable
    // immediately, tie-broken after the original (ascending id).
    let id = engine.insert(Some(ds.row(42)), None);
    assert_eq!(id, n);
    assert_eq!(engine.num_pending(), 1);
    let queries = ds.subset(&[42]);
    let res = engine.query(&queries, 5);
    assert_eq!(res[0][0].0, 42, "original not first");
    assert_eq!(res[0][1].0, n, "delta duplicate not second");
    assert!((res[0][1].1 - 1.0).abs() < 1e-5);
    // Compact: the delta folds into a fresh epoch, ids unchanged.
    assert!(engine.compact());
    assert!(!engine.compact(), "second compact had nothing to do");
    assert_eq!(engine.num_pending(), 0);
    assert_eq!(engine.num_indexed(), n as usize + 1);
    let res = engine.query(&queries, 5);
    assert_eq!(res[0][0].0, 42);
    assert_eq!(res[0][1].0, n, "compacted point lost from the index path");
    assert!((res[0][1].1 - 1.0).abs() < 1e-5);
}

#[test]
fn auto_compaction_triggers_at_the_limit() {
    let h = SimHash::new(16, 8, 7);
    let (ds, engine) = build_cosine_engine(&h, 2, 3);
    let before = engine.num_indexed();
    engine.insert(Some(ds.row(0)), None);
    engine.insert(Some(ds.row(1)), None);
    assert_eq!(engine.num_pending(), 2);
    engine.insert(Some(ds.row(2)), None);
    assert_eq!(engine.num_pending(), 0, "limit did not trigger compaction");
    assert_eq!(engine.num_indexed(), before + 3);
}

#[test]
fn set_measure_serving_self_retrieval() {
    // Weighted-Jaccard over Zipf token sets: the set-family serving path
    // (per-token CWS tables on the query side, hash-expanded query set in
    // the scoring kernel).
    let sets = synth::zipf_sets(500, &synth::ZipfSetsParams::default(), 29);
    let h = WeightedMinHash::new(3, 11);
    let params = BuildParams::threshold_mode(Algorithm::LshStars)
        .sketches(8)
        .threshold(0.1);
    let (_, index) = StarsBuilder::new(&sets)
        .similarity(&WeightedJaccardSim)
        .hash(&h)
        .params(params.clone())
        .workers(2)
        .build_indexed(ServeConfig::default().route_reps(6));
    let engine = QueryEngine::new(index, &h, ServeMeasure::WeightedJaccard, params).workers(2);
    let qids = [0u32, 99, 250, 499];
    let queries = sets.subset(&qids);
    let res = engine.query(&queries, 5);
    for (qi, &p) in qids.iter().enumerate() {
        assert!(!res[qi].is_empty(), "query {p} found nothing");
        assert_eq!(res[qi][0].0, p, "self not top-1 for set point {p}");
        assert!((res[qi][0].1 - 1.0).abs() < 1e-5);
    }
    // Streaming a new set point works end to end.
    let id = engine.insert(None, Some(sets.set(7).clone()));
    assert_eq!(id, 500);
    let res = engine.query(&sets.subset(&[7]), 3);
    assert!(res[0].iter().any(|&(i, _)| i == 500), "delta set not found");
}
