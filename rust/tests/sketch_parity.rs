//! Parity property tests for the data-parallel sketching subsystem:
//!
//! * the LSD radix argsort must reproduce the comparison sort's order,
//!   including index tie-breaks;
//! * the tiled multi-plane sketch kernel must produce bit-identical packed
//!   keys to the scalar per-row kernel across bit widths and dimensions;
//! * the restructured in-repetition-parallel `lsh_rep`/`sorting_rep` must
//!   produce edge vectors identical to the seed sequential per-rep path for
//!   fixed seeds, for every inner worker count;
//! * a full `StarsBuilder::build` must not depend on the worker count.

use stars::ampc::CostLedger;
use stars::data::synth;
use stars::data::types::Dataset;
use stars::graph::Edge;
use stars::lsh::{sketch, sorted_indices, windows, LshFamily, SimHash};
use stars::sim::{CosineSim, Similarity};
use stars::stars::threshold::{lsh_rep_par, score_all_pairs, score_stars};
use stars::stars::knn::sorting_rep_par;
use stars::stars::{
    group_buckets, sample_leaders, split_oversized, Algorithm, BuildParams, StarsBuilder,
};
use stars::util::quickcheck::check;
use stars::util::radix;
use stars::util::rng::{derive_seed, Rng};

#[test]
fn radix_argsort_matches_comparison_including_ties() {
    check("radix-vs-comparison", 30, |g| {
        let n = g.usize_in(0, 4000);
        // Narrow widths force heavy ties (and degenerate high-byte passes).
        let bits = [3usize, 16, 30, 64][g.usize_in(0, 3)];
        let mask = if bits == 64 {
            u64::MAX
        } else {
            (1u64 << bits) - 1
        };
        let keys: Vec<u64> = (0..n).map(|_| g.rng().next_u64() & mask).collect();
        let got = radix::argsort_u64(&keys);
        let mut want: Vec<u32> = (0..n as u32).collect();
        want.sort_unstable_by_key(|&i| (keys[i as usize], i));
        assert_eq!(got, want, "n={n} bits={bits}");
    });
}

#[test]
fn tiled_sketch_keys_bit_identical_to_scalar_kernel() {
    // 57 points: 14 full 4-row blocks plus a 1-row tail.
    for &bits in &[1usize, 7, 12, 30, 64] {
        for &d in &[3usize, 16, 100, 784] {
            let ds = synth::gaussian_mixture(57, d, 4, 0.3, (bits * 1000 + d) as u64);
            let h = SimHash::new(d, bits, 11);
            let planes = h.hyperplanes(2);
            let scalar: Vec<u64> = (0..ds.len()).map(|i| h.sketch_row(ds.row(i), &planes)).collect();
            assert_eq!(h.bucket_keys(&ds, 2), scalar, "bits={bits} d={d}");
            let packed: Vec<u64> = scalar
                .iter()
                .map(|k| k.reverse_bits() >> (64 - bits))
                .collect();
            assert_eq!(
                h.packed_sort_keys(&ds, 2),
                Some(packed),
                "packed bits={bits} d={d}"
            );
        }
    }
}

#[test]
fn parallel_sketch_drivers_bit_identical_to_scalar_kernel() {
    // Large enough that the drivers actually chunk across threads.
    let d = 16;
    let ds = synth::gaussian_mixture(2500, d, 8, 0.1, 29);
    let h = SimHash::new(d, 12, 3);
    let planes = h.hyperplanes(1);
    let scalar: Vec<u64> = (0..ds.len()).map(|i| h.sketch_row(ds.row(i), &planes)).collect();
    for workers in [1usize, 2, 7] {
        assert_eq!(sketch::bucket_keys_par(&h, &ds, 1, workers), scalar);
    }
}

/// The seed revision's sequential `lsh_rep` (Direct join): bucket, split,
/// then score each bucket in order against the shared repetition RNG.
fn lsh_rep_seed_reference(
    ds: &Dataset,
    sim: &dyn Similarity,
    family: &dyn LshFamily,
    params: &BuildParams,
    rep: u64,
    ledger: &CostLedger,
) -> Vec<Edge> {
    let mut rng = Rng::new(derive_seed(params.seed ^ 0x7E9, rep));
    let keys = family.bucket_keys(ds, rep);
    let buckets = split_oversized(group_buckets(&keys), params.max_bucket, &mut rng);
    let mut edges = Vec::new();
    let mut scores = Vec::new();
    for bucket in &buckets {
        if params.algorithm.is_stars() {
            score_stars(
                ds,
                sim,
                bucket,
                params.leaders,
                params.threshold,
                &mut rng,
                ledger,
                &mut scores,
                &mut edges,
            );
        } else {
            score_all_pairs(ds, sim, bucket, params.threshold, ledger, &mut scores, &mut edges);
        }
    }
    edges
}

/// The seed revision's sequential `sorting_rep`.
fn sorting_rep_seed_reference(
    ds: &Dataset,
    sim: &dyn Similarity,
    family: &dyn LshFamily,
    params: &BuildParams,
    rep: u64,
    ledger: &CostLedger,
) -> Vec<Edge> {
    let n = ds.len();
    let mut rng = Rng::new(derive_seed(params.seed ^ 0x50_47, rep));
    let order = sorted_indices(family, ds, rep);
    let mut edges = Vec::new();
    let mut scores = Vec::new();
    for w in windows(n, params.window, &mut rng) {
        let members = &order[w];
        if members.len() < 2 {
            continue;
        }
        if params.algorithm.is_stars() && members.len() > 2 * params.leaders {
            let leaders = sample_leaders(members.len(), params.leaders, &mut rng);
            for &lp in &leaders {
                let leader = members[lp];
                let (before, rest) = members.split_at(lp);
                let after = &rest[1..];
                for part in [before, after] {
                    if part.is_empty() {
                        continue;
                    }
                    sim.sim_batch(ds, leader as usize, part, &mut scores);
                    for (k, &c) in part.iter().enumerate() {
                        if scores[k] >= params.threshold {
                            edges.push(Edge::new(leader, c, scores[k]));
                        }
                    }
                }
            }
        } else {
            for (pos, &a) in members.iter().enumerate() {
                let rest = &members[pos + 1..];
                if rest.is_empty() {
                    continue;
                }
                sim.sim_batch(ds, a as usize, rest, &mut scores);
                for (k, &b) in rest.iter().enumerate() {
                    if scores[k] >= params.threshold {
                        edges.push(Edge::new(a, b, scores[k]));
                    }
                }
            }
        }
    }
    let _ = ledger;
    edges
}

#[test]
fn lsh_rep_parallel_matches_seed_path() {
    let ds = synth::gaussian_mixture(600, 16, 8, 0.08, 41);
    let h = SimHash::new(16, 8, 9);
    for algo in [Algorithm::LshStars, Algorithm::Lsh] {
        // Small leader count and bucket cap so both the leader-draw and the
        // sub-bucket-split RNG consumption are exercised.
        let params = BuildParams::threshold_mode(algo)
            .leaders(2)
            .max_bucket(40)
            .threshold(0.3)
            .seed(7);
        for rep in [0u64, 3] {
            let ledger = CostLedger::new(1);
            let want = lsh_rep_seed_reference(&ds, &CosineSim, &h, &params, rep, &ledger);
            assert!(!want.is_empty(), "reference produced no edges");
            for inner in [1usize, 2, 8] {
                let ledger = CostLedger::new(1);
                let got =
                    lsh_rep_par(&ds, &CosineSim, &h, &params, rep, &ledger, None, inner);
                assert_eq!(got, want, "{algo:?} rep={rep} inner={inner}");
            }
        }
    }
}

#[test]
fn sorting_rep_parallel_matches_seed_path() {
    let ds = synth::gaussian_mixture(700, 16, 8, 0.08, 43);
    let h = SimHash::new(16, 30, 13);
    for algo in [Algorithm::SortingLshStars, Algorithm::SortingLsh] {
        let params = BuildParams::knn_mode(algo).window(40).leaders(2).seed(19);
        for rep in [0u64, 5] {
            let ledger = CostLedger::new(1);
            let want = sorting_rep_seed_reference(&ds, &CosineSim, &h, &params, rep, &ledger);
            assert!(!want.is_empty(), "reference produced no edges");
            for inner in [1usize, 2, 8] {
                let ledger = CostLedger::new(1);
                let got = sorting_rep_par(&ds, &CosineSim, &h, &params, rep, &ledger, inner);
                assert_eq!(got, want, "{algo:?} rep={rep} inner={inner}");
            }
        }
    }
}

#[test]
fn build_graph_invariant_to_worker_count() {
    // R=3 sketches over up to 8 workers: small waves force inner workers
    // > 1, and the resulting graph must still be identical.
    let ds = synth::gaussian_mixture(800, 16, 8, 0.08, 31);
    for (family_bits, params) in [
        (
            8,
            BuildParams::threshold_mode(Algorithm::LshStars)
                .sketches(3)
                .leaders(3)
                .threshold(0.4)
                .seed(23),
        ),
        (
            30,
            BuildParams::knn_mode(Algorithm::SortingLshStars)
                .sketches(3)
                .window(50)
                .degree_cap(8)
                .seed(23),
        ),
    ] {
        let family = SimHash::new(16, family_bits, 5);
        let mut reference: Option<Vec<Edge>> = None;
        for workers in [1usize, 3, 8] {
            let out = StarsBuilder::new(&ds)
                .similarity(&CosineSim)
                .hash(&family)
                .params(params.clone())
                .workers(workers)
                .build();
            let edges = out.graph.edges().to_vec();
            assert!(!edges.is_empty());
            match &reference {
                None => reference = Some(edges),
                Some(want) => assert_eq!(
                    &edges, want,
                    "graph differs at workers={workers} ({:?})",
                    params.algorithm
                ),
            }
        }
    }
}
