//! Integration tests over the PJRT runtime + AOT artifacts.
//!
//! These exercise the full L1/L2 -> L3 bridge: HLO text produced by
//! python/compile/aot.py, loaded and executed from rust. They skip (with a
//! message) when `artifacts/` has not been built yet — `make test` builds it
//! first.

use stars::runtime::{ArtifactMeta, CosineScorer, Engine, LearnedModel, SimHashSketcher};
use stars::util::rng::Rng;

fn artifacts() -> Option<ArtifactMeta> {
    let dir = ArtifactMeta::default_dir();
    match ArtifactMeta::load(&dir) {
        Ok(m) => Some(m),
        Err(_) => {
            eprintln!("SKIP: artifacts not built (run `make artifacts`)");
            None
        }
    }
}

#[test]
fn cosine_scorer_matches_cpu_cosine() {
    let Some(meta) = artifacts() else { return };
    let engine = Engine::cpu().unwrap();
    let scorer = CosineScorer::load(&engine, &meta).unwrap();

    let mut rng = Rng::new(7);
    let (nl, nb, d) = (5usize, 300usize, 100usize);
    let leaders: Vec<f32> = (0..nl * d).map(|_| rng.gaussian() as f32).collect();
    let cands: Vec<f32> = (0..nb * d).map(|_| rng.gaussian() as f32).collect();
    let scores = scorer.score(&leaders, nl, &cands, nb, d).unwrap();
    assert_eq!(scores.len(), nl * nb);
    for li in 0..nl {
        for bi in 0..nb {
            let want = stars::sim::cosine(
                &leaders[li * d..(li + 1) * d],
                &cands[bi * d..(bi + 1) * d],
            );
            let got = scores[li * nb + bi];
            assert!(
                (got - want).abs() < 1e-4,
                "scorer mismatch at ({li},{bi}): {got} vs {want}"
            );
        }
    }
}

#[test]
fn cosine_scorer_handles_multi_dispatch_splits() {
    let Some(meta) = artifacts() else { return };
    let engine = Engine::cpu().unwrap();
    let scorer = CosineScorer::load(&engine, &meta).unwrap();
    // More leaders and candidates than one artifact dispatch holds.
    let (nl, nb, d) = (scorer.leaders + 3, scorer.block + 17, 64usize);
    let mut rng = Rng::new(9);
    let leaders: Vec<f32> = (0..nl * d).map(|_| rng.gaussian() as f32).collect();
    let cands: Vec<f32> = (0..nb * d).map(|_| rng.gaussian() as f32).collect();
    let before = scorer.dispatches();
    let scores = scorer.score(&leaders, nl, &cands, nb, d).unwrap();
    assert_eq!(scores.len(), nl * nb);
    assert!(scorer.dispatches() - before >= 4, "expected >= 4 dispatches");
    // Spot-check corners.
    for &(li, bi) in &[(0usize, 0usize), (nl - 1, nb - 1), (0, nb - 1), (nl - 1, 0)] {
        let want = stars::sim::cosine(
            &leaders[li * d..(li + 1) * d],
            &cands[bi * d..(bi + 1) * d],
        );
        assert!((scores[li * nb + bi] - want).abs() < 1e-4);
    }
}

#[test]
fn simhash_sketcher_is_locality_sensitive() {
    let Some(meta) = artifacts() else { return };
    let engine = Engine::cpu().unwrap();
    let sketcher = SimHashSketcher::load(&engine, &meta).unwrap();
    let d = 100usize;
    let mut rng = Rng::new(11);
    // Pairs: (base, base+tiny noise) and (base, random).
    let n = 40usize;
    let mut rows = Vec::with_capacity(2 * n * d);
    for _ in 0..n {
        let base: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
        rows.extend(base.iter().map(|x| x + 0.01 * rng.gaussian() as f32));
        rows.extend(base);
    }
    let keys = sketcher.sketch(&rows, 2 * n, d).unwrap();
    // Near-duplicates share most sketch bits.
    let mut near_ham = 0u32;
    let mut far_ham = 0u32;
    for i in 0..n {
        near_ham += (keys[2 * i] ^ keys[2 * i + 1]).count_ones();
        far_ham += (keys[2 * i] ^ keys[(2 * i + 3) % (2 * n)]).count_ones();
    }
    assert!(
        near_ham * 4 < far_ham,
        "near pairs hamming {near_ham} not << far {far_ham}"
    );
    // Determinism.
    let keys2 = sketcher.sketch(&rows, 2 * n, d).unwrap();
    assert_eq!(keys, keys2);
}

#[test]
fn learned_model_matches_python_golden() {
    let Some(meta) = artifacts() else { return };
    let path = meta.dir.join("learned_sim_golden.bin");
    let Ok(bytes) = std::fs::read(&path) else {
        eprintln!("SKIP: no golden file");
        return;
    };
    // Parse: u64 count, then per section u64 len + f32 data.
    let mut off = 0usize;
    let read_u64 = |b: &[u8], o: &mut usize| {
        let v = u64::from_le_bytes(b[*o..*o + 8].try_into().unwrap());
        *o += 8;
        v
    };
    let nsec = read_u64(&bytes, &mut off);
    assert_eq!(nsec, 6);
    let mut sections: Vec<Vec<f32>> = Vec::new();
    for _ in 0..nsec {
        let len = read_u64(&bytes, &mut off) as usize;
        let mut v = vec![0f32; len];
        for x in v.iter_mut() {
            *x = f32::from_le_bytes(bytes[off..off + 4].try_into().unwrap());
            off += 4;
        }
        sections.push(v);
    }
    let engine = Engine::cpu().unwrap();
    let model = LearnedModel::load(&engine, &meta).unwrap();
    let m = model.meta;
    let b = m.batch;
    assert_eq!(sections[0].len(), b * m.dim);
    assert_eq!(sections[4].len(), b * m.pair_feats);
    let want = &sections[5];

    // Execute via the raw artifact path (bypassing featurization, which the
    // golden batch already did in python).
    let inputs = [
        stars::runtime::literal_f32(&sections[0], &[b as i64, m.dim as i64]).unwrap(),
        stars::runtime::literal_f32(&sections[1], &[b as i64, m.hash_buckets as i64]).unwrap(),
        stars::runtime::literal_f32(&sections[2], &[b as i64, m.dim as i64]).unwrap(),
        stars::runtime::literal_f32(&sections[3], &[b as i64, m.hash_buckets as i64]).unwrap(),
        stars::runtime::literal_f32(&sections[4], &[b as i64, m.pair_feats as i64]).unwrap(),
    ];
    let exe = engine.load_hlo_text(&meta.file("learned_sim").unwrap()).unwrap();
    let got = exe.run_f32(&inputs).unwrap();
    assert_eq!(got.len(), b);
    for i in 0..b {
        assert!(
            (got[i] - want[i]).abs() < 1e-4,
            "learned model mismatch at {i}: {} vs {}",
            got[i],
            want[i]
        );
    }
}

#[test]
fn learned_model_scores_same_class_higher() {
    let Some(meta) = artifacts() else { return };
    let engine = Engine::cpu().unwrap();
    let model = LearnedModel::load(&engine, &meta).unwrap();
    // Generate products with the same recipe seed the model was trained on.
    let seed = meta
        .raw
        .get("recipe_seed")
        .and_then(|v| v.as_usize())
        .unwrap_or(42) as u64;
    let ds = stars::data::synth::products(
        400,
        &stars::data::synth::ProductsParams::default(),
        seed,
    );
    let mut same_pairs = Vec::new();
    let mut diff_pairs = Vec::new();
    for i in 0..200u32 {
        for j in (i + 1)..200u32 {
            if ds.labels[i as usize] == ds.labels[j as usize] {
                same_pairs.push((i, j));
            } else if diff_pairs.len() < 400 {
                diff_pairs.push((i, j));
            }
        }
    }
    assert!(!same_pairs.is_empty());
    let s_same = model.score(&ds, &same_pairs).unwrap();
    let s_diff = model.score(&ds, &diff_pairs).unwrap();
    let mean = |v: &[f32]| v.iter().sum::<f32>() as f64 / v.len() as f64;
    assert!(
        mean(&s_same) > mean(&s_diff) + 0.3,
        "learned sim does not separate classes: {} vs {}",
        mean(&s_same),
        mean(&s_diff)
    );
    // AUC recorded at train time should be good.
    assert!(model.auc > 0.85, "train-time AUC {}", model.auc);
}
