//! Property-based tests of the paper's theoretical guarantees.
//!
//! * Theorem 3.1 — Stars 1 output is an (r₁, r₂)-two-hop spanner w.h.p.:
//!   no edge below r₁; pairs above r₂ connected within two hops.
//! * Theorem 2.5 / Obs A.1 — spanner connected components sandwich the
//!   threshold-graph components; single-linkage via spanners approximates
//!   the exact objective.
//! * Theorem 3.4 (qualitative) — Stars 2 captures approximate k-NN in the
//!   two-hop neighborhood with nearly-linear comparisons.

use stars::clustering::{single_linkage_k, sweep_components};
use stars::data::synth;
use stars::graph::two_hop::spanner_violations;
use stars::graph::{Csr, Graph};
use stars::lsh::SimHash;
use stars::sim::{CosineSim, Similarity};
use stars::stars::{allpair, Algorithm, BuildParams, StarsBuilder};
use stars::util::quickcheck::{check, Gen};

/// Build a Stars 1 spanner and verify Definition 2.4 on explicit pairs.
#[test]
fn stars1_is_a_two_hop_spanner_whp() {
    check("stars1-spanner", 6, |g: &mut Gen| {
        let n = 200 + g.usize_in(0, 400);
        let modes = 3 + g.usize_in(0, 5);
        let seed = g.usize_in(0, 1 << 30) as u64;
        let ds = synth::gaussian_mixture(n, 32, modes, 0.06, seed);
        let (r1, r2) = (0.5f32, 0.7f32);
        let family = SimHash::new(32, 6, seed ^ 1);
        let out = StarsBuilder::new(&ds)
            .similarity(&CosineSim)
            .hash(&family)
            .params(
                BuildParams::threshold_mode(Algorithm::LshStars)
                    .sketches(80)
                    .threshold(r1)
                    .degree_cap(0)
                    .seed(seed ^ 2),
            )
            .workers(4)
            .build();
        // Required pairs: everything with similarity >= r2.
        let cluster = stars::ampc::Cluster::new(2);
        let required: Vec<(u32, u32)> =
            allpair::allpair_edges(&ds, &CosineSim, r2, &cluster)
                .into_iter()
                .map(|e| (e.u, e.v))
                .collect();
        let csr = Csr::new(&out.graph);
        let (missing, bad_edges) = spanner_violations(&csr, &required, r1);
        // Condition (1) of Def 2.4 holds deterministically.
        assert_eq!(bad_edges, 0, "edges below r1 exist");
        // Condition (2) holds w.h.p.: allow a small miss rate.
        let allowed = required.len() / 20 + 2;
        assert!(
            missing <= allowed,
            "{missing}/{} required pairs not within two hops",
            required.len()
        );
    });
}

/// Observation A.1 sandwich on random datasets.
#[test]
fn spanner_components_sandwich() {
    check("component-sandwich", 5, |g: &mut Gen| {
        let n = 150 + g.usize_in(0, 250);
        let seed = g.usize_in(0, 1 << 30) as u64;
        let ds = synth::gaussian_mixture(n, 24, 4, 0.06, seed);
        let (r, c) = (0.6f32, 1.25f32);
        let r1 = r / c;
        let family = SimHash::new(24, 5, seed ^ 3);
        let out = StarsBuilder::new(&ds)
            .similarity(&CosineSim)
            .hash(&family)
            .params(
                BuildParams::threshold_mode(Algorithm::LshStars)
                    .sketches(80)
                    .threshold(r1)
                    .degree_cap(0)
                    .seed(seed ^ 4),
            )
            .workers(2)
            .build();
        let cluster = stars::ampc::Cluster::new(2);
        let lo = Graph::from_edges(n, allpair::allpair_edges(&ds, &CosineSim, r1, &cluster));
        let hi = Graph::from_edges(n, allpair::allpair_edges(&ds, &CosineSim, r, &cluster));
        let lo_cc = sweep_components(&lo, f32::MIN);
        let hi_cc = sweep_components(&hi, f32::MIN);
        let sp_cc = sweep_components(&out.graph, f32::MIN);
        assert!(
            lo_cc <= sp_cc && sp_cc <= hi_cc,
            "sandwich violated: {lo_cc} <= {sp_cc} <= {hi_cc}"
        );
    });
}

/// Single-linkage on the spanner approximates single-linkage on the exact
/// threshold graph: the k-clustering cost (max cross-cluster similarity)
/// from the spanner is within the [r/c, r] guarantee band.
#[test]
fn single_linkage_two_approximation() {
    check("single-linkage-approx", 4, |g: &mut Gen| {
        let n = 150 + g.usize_in(0, 150);
        let seed = g.usize_in(0, 1 << 30) as u64;
        let ds = synth::gaussian_mixture(n, 24, 5, 0.06, seed);
        let cluster = stars::ampc::Cluster::new(2);
        // Exact graph at a low threshold so plenty of edges exist.
        let exact = Graph::from_edges(
            n,
            allpair::allpair_edges(&ds, &CosineSim, 0.2, &cluster),
        );
        let family = SimHash::new(24, 5, seed ^ 7);
        let out = StarsBuilder::new(&ds)
            .similarity(&CosineSim)
            .hash(&family)
            .params(
                BuildParams::threshold_mode(Algorithm::LshStars)
                    .sketches(100)
                    .threshold(0.2)
                    .degree_cap(0)
                    .seed(seed ^ 8),
            )
            .workers(2)
            .build();
        let k = 5;
        let (_, cost_exact) = single_linkage_k(&exact, k);
        let (_, cost_spanner) = single_linkage_k(&out.graph, k);
        if cost_exact.is_finite() && cost_spanner.is_finite() {
            // The spanner misses some edges, so its merge order may differ;
            // its achieved objective must not be grossly worse: the max
            // cross-cluster similarity can exceed the optimum only by edges
            // the spanner failed to merge, bounded in similarity by the
            // two-hop guarantee. Allow a generous band.
            assert!(
                cost_spanner <= cost_exact + 0.25,
                "spanner single-linkage cost {cost_spanner} vs exact {cost_exact}"
            );
        }
    });
}

/// Theorem 3.4 (qualitative): Stars 2 puts most true k-NN within two hops
/// while doing ~s/W of the baseline's comparisons per window.
#[test]
fn stars2_knn_coverage_property() {
    check("stars2-knn", 3, |g: &mut Gen| {
        let n = 600 + g.usize_in(0, 400);
        let seed = g.usize_in(0, 1 << 30) as u64;
        let ds = synth::gaussian_mixture(n, 32, 20, 0.1, seed);
        let family = SimHash::new(32, 30, seed ^ 9);
        let k = 10;
        let out = StarsBuilder::new(&ds)
            .similarity(&CosineSim)
            .hash(&family)
            .params(
                BuildParams::knn_mode(Algorithm::SortingLshStars)
                    .sketches(20)
                    .window(16 * k) // the paper's W = 16k
                    .seed(seed ^ 10),
            )
            .workers(4)
            .build();
        let cluster = stars::ampc::Cluster::new(2);
        let truth = allpair::exact_knn(&ds, &CosineSim, k, &cluster);
        let csr = Csr::new(&out.graph);
        let queries = stars::eval::recall::sample_queries(n, 100, seed);
        let rec = stars::eval::recall::knn_recall(&ds, &CosineSim, &csr, &truth, &queries, k, 0.99);
        assert!(
            rec.two_hop > 0.6,
            "two-hop knn coverage only {:?} (n={n})",
            rec
        );
    });
}

/// Edge weights always equal the true similarity of their endpoints (the
/// algorithms never fabricate weights).
#[test]
fn edge_weights_are_true_similarities() {
    let ds = synth::gaussian_mixture(400, 16, 8, 0.1, 44);
    let family = SimHash::new(16, 8, 2);
    for algo in [Algorithm::LshStars, Algorithm::Lsh, Algorithm::SortingLshStars] {
        let params = match algo {
            Algorithm::SortingLshStars => BuildParams::knn_mode(algo).sketches(6).window(40),
            _ => BuildParams::threshold_mode(algo).sketches(6),
        };
        let out = StarsBuilder::new(&ds)
            .similarity(&CosineSim)
            .hash(&family)
            .params(params)
            .workers(2)
            .build();
        for e in out.graph.edges().iter().take(500) {
            let want = CosineSim.sim(&ds, e.u as usize, e.v as usize);
            assert!(
                (e.w - want).abs() < 1e-5,
                "{algo:?} edge ({},{}) weight {} != sim {}",
                e.u,
                e.v,
                e.w,
                want
            );
        }
    }
}
