//! Fault-injection acceptance tests: the hard invariant is that a build
//! under *any* seeded fault schedule — crashes, delays, corrupted shuffle
//! partitions / DHT batches — produces bit-identical output to the
//! fault-free build (edges, CSR, and serve top-k), for one worker and for
//! many, while the recovery counters on the report prove the schedule
//! actually fired. Recovery is pure re-execution of deterministic tasks,
//! so anything short of bit-identity is a recovery bug.
//!
//! Every build here pins its plan via [`StarsBuilder::faults`] — never the
//! `STARS_FAULTS` env var, which races across parallel test threads (and
//! which `scripts/ci.sh` sets for whole re-runs of this file; the explicit
//! pins make those runs exercise exactly the same schedules).
//!
//! The overload tests at the bottom cover the serve-side half of the
//! robustness story: the [`FrontDoor`] admission ladder sheds and degrades
//! under synthetic pressure while admitted queries stay bit-identical to a
//! door-less engine.

use stars::data::synth;
use stars::lsh::SimHash;
use stars::serve::{
    Admission, AdmissionConfig, FrontDoor, QueryEngine, ServeConfig, ServeMeasure, ShardedEngine,
    ShedReason,
};
use stars::sim::CosineSim;
use stars::stars::{Algorithm, BuildOutput, BuildParams, JoinStrategy, StarsBuilder};
use stars::util::fault::FaultPlan;

fn fixture() -> stars::data::Dataset {
    synth::gaussian_mixture(800, 16, 10, 0.08, 33)
}

fn params(join: JoinStrategy) -> BuildParams {
    BuildParams::threshold_mode(Algorithm::LshStars)
        .sketches(6)
        .threshold(0.4)
        .join(join)
}

fn build_with(
    ds: &stars::data::Dataset,
    h: &SimHash,
    plan: FaultPlan,
    workers: usize,
    join: JoinStrategy,
) -> BuildOutput {
    StarsBuilder::new(ds)
        .similarity(&CosineSim)
        .hash(h)
        .params(params(join))
        .workers(workers)
        .faults(plan)
        .build()
}

fn plan(spec: &str) -> FaultPlan {
    FaultPlan::parse(spec).expect("test plan spec")
}

#[test]
fn crash_schedule_build_is_bit_identical() {
    let ds = fixture();
    let h = SimHash::new(16, 8, 7);
    let clean = build_with(&ds, &h, FaultPlan::none(), 1, JoinStrategy::Direct);
    assert!(!clean.report.faults.any(), "inert plan must count nothing");
    for workers in [1usize, 4] {
        let out = build_with(
            &ds,
            &h,
            plan("seed=11,crash=0.9,max_failures=2"),
            workers,
            JoinStrategy::Direct,
        );
        assert_eq!(
            out.graph.edges(),
            clean.graph.edges(),
            "crash schedule changed the graph ({workers} workers)"
        );
        assert!(
            out.report.faults.injected_crashes > 0,
            "schedule never fired ({workers} workers)"
        );
        assert!(out.report.faults.task_retries > 0);
    }
}

#[test]
fn delay_schedule_build_is_bit_identical() {
    let ds = fixture();
    let h = SimHash::new(16, 8, 7);
    let clean = build_with(&ds, &h, FaultPlan::none(), 1, JoinStrategy::Direct);
    for workers in [1usize, 4] {
        let out = build_with(
            &ds,
            &h,
            plan("seed=5,delay=0.95:30"),
            workers,
            JoinStrategy::Direct,
        );
        assert_eq!(
            out.graph.edges(),
            clean.graph.edges(),
            "delay schedule changed the graph ({workers} workers)"
        );
        assert!(
            out.report.faults.injected_delays > 0,
            "schedule never fired ({workers} workers)"
        );
    }
}

#[test]
fn corruption_schedules_build_bit_identical() {
    let ds = fixture();
    let h = SimHash::new(16, 8, 7);
    for join in [JoinStrategy::Shuffle, JoinStrategy::Dht] {
        let clean = build_with(&ds, &h, FaultPlan::none(), 1, join);
        for workers in [1usize, 4] {
            let out = build_with(
                &ds,
                &h,
                plan("seed=9,corrupt=0.9,max_failures=2"),
                workers,
                join,
            );
            assert_eq!(
                out.graph.edges(),
                clean.graph.edges(),
                "corruption changed the graph ({join:?}, {workers} workers)"
            );
            assert!(
                out.report.faults.corruption_retries > 0,
                "no checksum retries fired ({join:?}, {workers} workers)"
            );
        }
    }
}

#[test]
fn total_crash_schedule_recovers_via_wave_restarts() {
    // crash=1.0 with max_failures=5: every task crashes three times in its
    // first wave (exhausting the in-place retry budget → wave restart),
    // twice more in the restarted wave, then runs clean because the
    // persistent per-(round, task) failure record crossed the budget. The
    // build must complete with the exact fault-free graph.
    let ds = fixture();
    let h = SimHash::new(16, 8, 7);
    let clean = build_with(&ds, &h, FaultPlan::none(), 1, JoinStrategy::Direct);
    let out = build_with(
        &ds,
        &h,
        plan("seed=2,crash=1.0,max_failures=5"),
        4,
        JoinStrategy::Direct,
    );
    assert_eq!(out.graph.edges(), clean.graph.edges());
    assert!(out.report.faults.wave_restarts > 0, "no wave ever restarted");
    assert!(out.report.faults.injected_crashes >= 5);
}

#[test]
fn serve_topk_is_bit_identical_under_faults() {
    // End to end: a faulted build's serving snapshot answers every query
    // exactly like the fault-free one, across worker counts.
    let ds = fixture();
    let h = SimHash::new(16, 8, 7);
    let qids: Vec<u32> = (0..800u32).step_by(37).collect();
    let queries = ds.subset(&qids);
    let serve_cfg = || ServeConfig::default().route_reps(6).compact_limit(0);
    let build_engine = |plan: FaultPlan, workers: usize| {
        let p = params(JoinStrategy::Direct);
        let (out, index) = StarsBuilder::new(&ds)
            .similarity(&CosineSim)
            .hash(&h)
            .params(p.clone())
            .workers(workers)
            .faults(plan)
            .build_indexed(serve_cfg());
        (
            out.report.faults,
            QueryEngine::new(index, &h, ServeMeasure::Cosine, p).workers(workers),
        )
    };
    let (_, clean) = build_engine(FaultPlan::none(), 1);
    let baseline = clean.query(&queries, 10);
    drop(clean);
    for workers in [1usize, 4] {
        let (counters, engine) =
            build_engine(plan("seed=17,crash=0.8,delay=0.5:25,max_failures=2"), workers);
        assert!(counters.any(), "mixed schedule never fired");
        assert_eq!(
            engine.query(&queries, 10),
            baseline,
            "faulted build serves different top-k ({workers} workers)"
        );
    }
}

#[test]
fn sharded_scatter_is_bit_identical_under_faults() {
    // Scatter tasks under crash/delay schedules re-execute (straggler
    // re-execution: the retry loop in the scatter path) and the gathered
    // answers stay bit-identical to a fault-free sharded engine — on the
    // snapshot path and with a live delta.
    let ds = fixture();
    let h = SimHash::new(16, 8, 7);
    let p = params(JoinStrategy::Direct);
    let qids: Vec<u32> = (0..800u32).step_by(37).collect();
    let queries = ds.subset(&qids);
    let (_, base) = StarsBuilder::new(&ds)
        .similarity(&CosineSim)
        .hash(&h)
        .params(p.clone())
        .build_sharded(
            1,
            ServeConfig::default().route_reps(6).compact_limit(0),
        );
    let clean =
        ShardedEngine::new(base.resharded(4), &h, ServeMeasure::Cosine, p.clone()).workers(4);
    let want = clean.query(&queries, 10);
    assert_eq!(clean.scatter_retries(), 0, "inert plan must count nothing");
    for spec in [
        "seed=3,crash=0.8,max_failures=2",
        "seed=5,crash=0.5,delay=0.4:5,max_failures=3",
    ] {
        let eng = ShardedEngine::new(base.resharded(4), &h, ServeMeasure::Cosine, p.clone())
            .workers(4)
            .faults(plan(spec));
        assert_eq!(
            eng.query(&queries, 10),
            want,
            "faulted scatter diverged ({spec})"
        );
        assert!(eng.scatter_retries() > 0, "plan never fired ({spec})");
        // Delta path under the same schedule: the same insert into a fresh
        // fault-free engine must still gather bit-identically.
        let clean_delta =
            ShardedEngine::new(base.resharded(4), &h, ServeMeasure::Cosine, p.clone())
                .workers(4);
        eng.insert(Some(ds.row(3)), None);
        clean_delta.insert(Some(ds.row(3)), None);
        assert_eq!(
            eng.query(&queries, 10),
            clean_delta.query(&queries, 10),
            "faulted delta scatter diverged ({spec})"
        );
    }
}

#[test]
fn front_door_releases_permits_when_the_engine_panics() {
    // The no-leak property: AdmissionPermit::drop runs during unwind, so a
    // query that panics inside the engine cannot wedge the door. Six
    // panicking batches against a queue_limit of 4 would exhaust the queue
    // if any permit leaked — later panics would shed instead of panic, and
    // the final good batch would be refused.
    let ds = fixture();
    let h = SimHash::new(16, 8, 7);
    let p = params(JoinStrategy::Direct);
    let (_, base) = StarsBuilder::new(&ds)
        .similarity(&CosineSim)
        .hash(&h)
        .params(p.clone())
        .build_sharded(
            3,
            ServeConfig::default().route_reps(6).compact_limit(0),
        );
    let engine = ShardedEngine::new(base, &h, ServeMeasure::Cosine, p).workers(2);
    let door = FrontDoor::new(&engine, AdmissionConfig::default().queue_limit(4));
    let good = ds.subset(&[1, 2]);
    assert!(!door.query(&good, 5).is_shed(), "cold door must admit");
    assert_eq!(door.depth(), 0);
    // Wrong-dimension queries panic inside the engine (its dim assert).
    let bad = synth::gaussian_mixture(3, 8, 2, 0.05, 1);
    for round in 0..6 {
        let got =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| door.query(&bad, 5)));
        assert!(got.is_err(), "dim-mismatched query must panic (round {round})");
        assert_eq!(
            door.depth(),
            0,
            "panicked query leaked its permit (round {round})"
        );
    }
    assert!(
        !door.query(&good, 5).is_shed(),
        "door wedged after panicking queries"
    );
    assert_eq!(door.stats().queue_sheds, 0);
}

/// Quantized engine fixture for the admission tests (the degraded tier
/// needs an SQ8 table on the snapshot).
fn quantized_engine(h: &SimHash, workers: usize) -> (stars::data::Dataset, QueryEngine<'_>) {
    let ds = fixture();
    let p = params(JoinStrategy::Direct);
    let (_, index) = StarsBuilder::new(&ds)
        .similarity(&CosineSim)
        .hash(h)
        .params(p.clone())
        .workers(workers)
        .faults(FaultPlan::none())
        .build_indexed(
            ServeConfig::default()
                .route_reps(6)
                .compact_limit(0)
                .quantized(4),
        );
    let engine = QueryEngine::new(index, h, ServeMeasure::Cosine, p).workers(workers);
    (ds, engine)
}

#[test]
fn front_door_admits_degrades_and_sheds_in_order() {
    let h = SimHash::new(16, 8, 7);
    let (ds, engine) = quantized_engine(&h, 2);
    let qids: Vec<u32> = (0..800u32).step_by(53).collect();
    let queries = ds.subset(&qids);
    let door = FrontDoor::new(
        &engine,
        AdmissionConfig::default()
            .queue_limit(4)
            .degrade_at(0.5)
            .degraded_rescore(2),
    );

    // Unloaded: admitted results are bit-identical to the door-less engine.
    match door.query(&queries, 10) {
        Admission::Served(got) => assert_eq!(got, engine.query(&queries, 10)),
        other => panic!("unloaded query not served untouched: {other:?}"),
    }

    // One held permit puts the query at depth 2 = degrade_at × queue_limit:
    // served on the degraded tier, bit-identical to query_tier at the
    // reduced rescore width.
    let _backlog = door.acquire().expect("depth 1 admits");
    match door.query(&queries, 10) {
        Admission::Degraded(got) => {
            assert_eq!(got, engine.query_tier(&queries, 10, Some(2)));
        }
        other => panic!("pressured query not degraded: {other:?}"),
    }

    // Fill the queue: the next query is shed without computing anything.
    let _b2 = door.acquire().expect("depth 2 admits");
    let _b3 = door.acquire().expect("depth 3 admits");
    let _b4 = door.acquire().expect("depth 4 admits");
    assert!(door.acquire().is_none(), "queue_limit must bound depth");
    match door.query(&queries, 10) {
        Admission::Shed(ShedReason::QueueFull) => {}
        other => panic!("overloaded query not shed: {other:?}"),
    }

    let stats = door.stats();
    assert_eq!(stats.admitted, 2);
    assert_eq!(stats.degraded, 1);
    assert!(stats.queue_sheds >= 2, "permit denial and query shed both count");
    assert_eq!(stats.deadline_sheds, 0);
    assert!(
        stats.depth_high_water <= 4,
        "depth exceeded queue_limit: {}",
        stats.depth_high_water
    );
    assert!(stats.p99_ms >= stats.p50_ms);
    assert!(stats.ewma_ms > 0.0);
    assert!(stats.shed() >= 2);
}

#[test]
fn front_door_deadline_shedding_uses_the_ewma() {
    let h = SimHash::new(16, 8, 7);
    let (ds, engine) = quantized_engine(&h, 2);
    let queries = ds.subset(&[1, 50, 99]);
    let door = FrontDoor::new(
        &engine,
        AdmissionConfig::default()
            .queue_limit(8)
            .degrade_at(0.0)
            .deadline_ms(1e-4),
    );
    // First query warms the EWMA (no estimate yet → deadline check skips).
    assert!(!door.query(&queries, 5).is_shed(), "cold door must admit");
    assert!(door.ewma_ms() > 0.0);
    // With backlog held, the estimated wait dwarfs the microscopic budget.
    let _b1 = door.acquire().unwrap();
    let _b2 = door.acquire().unwrap();
    match door.query(&queries, 5) {
        Admission::Shed(ShedReason::Deadline) => {}
        other => panic!("doomed query not deadline-shed: {other:?}"),
    }
    let stats = door.stats();
    assert_eq!(stats.deadline_sheds, 1);
    assert_eq!(stats.admitted, 1);
}
