//! Durability acceptance battery for `stars::serve::durable` (the PR-10
//! tentpole): WAL framing and torn-tail truncation, corrupted-persistence
//! fuzzing (bit flips + truncation over every section boundary — errors
//! with per-section context, never a panic), and the crash-recovery
//! bit-identity contract: after a simulated crash at *any* WAL record
//! boundary or inside a torn append, recovery (newest valid snapshot +
//! WAL-suffix replay) must answer top-k bit-identical to a process that
//! never crashed — for the exact and quantized tiers, across worker
//! counts, and through the sharded scatter-gather engine.
//!
//! `scripts/ci.sh` adds the process-level twin of this battery: a CLI
//! serve run killed mid-WAL-append by an injected fault, restarted, and
//! required to report the same `results_digest` as a clean run.

use stars::data::synth;
use stars::data::types::WeightedSet;
use stars::lsh::{SimHash, WeightedMinHash};
use stars::serve::durable::{
    read_wal, save_snapshot, snapshot_path, wal_path, WalRecord, WalWriter,
};
use stars::serve::{
    DurableStore, FsyncPolicy, QueryEngine, ServeConfig, ServeMeasure, ShardedEngine,
    ShardedIndex,
};
use stars::sim::{CosineSim, WeightedJaccardSim};
use stars::stars::{Algorithm, BuildParams, StarsBuilder};
use std::path::PathBuf;

fn tmp_dir(name: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("stars-durability-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&p);
    std::fs::create_dir_all(&p).unwrap();
    p
}

fn sample_records(n: usize) -> Vec<WalRecord> {
    (0..n)
        .map(|i| WalRecord {
            gid: 400 + i as u32,
            row: Some((0..16).map(|d| (i * 16 + d) as f32 * 0.25 - 3.0).collect()),
            set: (i % 3 == 0).then(|| WeightedSet {
                tokens: vec![i as u32, i as u32 + 7],
                weights: vec![1.0, 0.5 + i as f32],
            }),
        })
        .collect()
}

// ---------------------------------------------------------------- WAL layer

#[test]
fn wal_roundtrips_rows_sets_and_fsync_policies() {
    let dir = tmp_dir("wal-roundtrip");
    let recs = sample_records(9);
    for (name, policy) in [
        ("always", FsyncPolicy::Always),
        ("os", FsyncPolicy::Os),
        ("every", FsyncPolicy::EveryN(4)),
    ] {
        let path = dir.join(format!("{name}.log"));
        let mut w = WalWriter::create(&path, policy).unwrap();
        for r in &recs {
            w.append(r).unwrap();
        }
        w.sync().unwrap();
        let (got, torn) = read_wal(&path).unwrap();
        assert_eq!(got, recs, "policy {name} altered records");
        assert_eq!(torn, 0, "clean file reported a torn tail");
    }
    assert_eq!(FsyncPolicy::parse("always").unwrap(), FsyncPolicy::Always);
    assert_eq!(FsyncPolicy::parse("os").unwrap(), FsyncPolicy::Os);
    assert_eq!(FsyncPolicy::parse("every:16").unwrap(), FsyncPolicy::EveryN(16));
    assert!(FsyncPolicy::parse("every:0").is_err());
    assert!(FsyncPolicy::parse("sometimes").is_err());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn wal_torn_tail_truncates_to_the_last_complete_record() {
    // A crash can land at any byte of an in-flight append: every torn
    // length must read back as exactly the complete prefix, with the torn
    // byte count reported.
    let dir = tmp_dir("wal-torn");
    let recs = sample_records(5);
    let extra = sample_records(6);
    let torn_rec = &extra[5];
    for keep in [0usize, 1, 4, 7, 8, 9, 20, 10_000] {
        let path = dir.join(format!("torn-{keep}.log"));
        let mut w = WalWriter::create(&path, FsyncPolicy::Os).unwrap();
        for r in &recs {
            w.append(r).unwrap();
        }
        let kept = w.append_torn(torn_rec, keep).unwrap();
        assert!(kept <= keep, "append_torn wrote more than asked");
        let (got, torn) = read_wal(&path).unwrap();
        assert_eq!(got, recs, "torn tail (keep={keep}) corrupted the prefix");
        assert_eq!(torn, kept, "torn byte count wrong for keep={keep}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn wal_fuzz_truncation_and_bit_flips_never_panic() {
    let dir = tmp_dir("wal-fuzz");
    let path = dir.join("base.log");
    let recs = sample_records(6);
    let mut w = WalWriter::create(&path, FsyncPolicy::Os).unwrap();
    for r in &recs {
        w.append(r).unwrap();
    }
    w.sync().unwrap();
    drop(w);
    let bytes = std::fs::read(&path).unwrap();
    let scratch = dir.join("scratch.log");
    // Truncation at every byte offset: the reader must return a prefix of
    // the original records (or an error), never panic, never invent data.
    for cut in 0..=bytes.len() {
        std::fs::write(&scratch, &bytes[..cut]).unwrap();
        if let Ok((got, _)) = read_wal(&scratch) {
            assert!(got.len() <= recs.len());
            assert_eq!(got[..], recs[..got.len()], "truncation at {cut} invented records");
        }
    }
    // One flipped bit at every byte offset: prefix-or-error, and any
    // record the reader does return must be byte-exact from the original
    // prefix (the CRC catches everything downstream of the flip).
    for at in 0..bytes.len() {
        let mut mutated = bytes.clone();
        mutated[at] ^= 0x10;
        std::fs::write(&scratch, &mutated).unwrap();
        if let Ok((got, _)) = read_wal(&scratch) {
            assert!(got.len() <= recs.len(), "flip at {at} invented records");
            for (i, r) in got.iter().enumerate() {
                if *r != recs[i] {
                    // A flip inside record i's payload that still passed
                    // CRC-32 would be a checksum collision from a single
                    // bit flip — impossible for CRC-32.
                    panic!("flip at byte {at} silently altered record {i}");
                }
            }
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// ----------------------------------------------------------- snapshot layer

/// Section boundaries of a snapshot file: byte offsets of every structural
/// edge (header fields, then each section's tag / len / crc / payload
/// start / payload end), parsed from the on-disk layout
/// (`MAGIC ∥ version ∥ count ∥ [tag(4) len(8) crc(4) payload]*`).
fn section_boundaries(bytes: &[u8]) -> Vec<usize> {
    let mut cuts = vec![0, 4, 8, 12];
    let count = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
    let mut at = 12usize;
    for _ in 0..count {
        let len = u64::from_le_bytes(bytes[at + 4..at + 12].try_into().unwrap()) as usize;
        cuts.extend([at + 4, at + 12, at + 16, at + 16 + len / 2, at + 16 + len]);
        at += 16 + len;
    }
    assert_eq!(at, bytes.len(), "section table does not tile the file");
    cuts
}

fn build_cosine_index(
    h: &SimHash,
    quantized: bool,
) -> (stars::data::Dataset, stars::serve::StarIndex<'_>, BuildParams, ServeConfig) {
    let ds = synth::gaussian_mixture(400, 16, 8, 0.08, 33);
    let params = BuildParams::threshold_mode(Algorithm::LshStars)
        .sketches(6)
        .threshold(0.5);
    let mut cfg = ServeConfig::default()
        .route_reps(6)
        .compact_limit(0)
        .max_candidates(0)
        .seal_limit(5);
    if quantized {
        cfg = cfg.quantized(4);
    }
    let (_, index) = StarsBuilder::new(&ds)
        .similarity(&CosineSim)
        .hash(h)
        .params(params.clone())
        .workers(2)
        .build_indexed(cfg.clone());
    (ds, index, params, cfg)
}

#[test]
fn snapshot_roundtrip_is_bit_identical_for_both_tiers() {
    let h = SimHash::new(16, 8, 7);
    for quantized in [false, true] {
        let dir = tmp_dir(&format!("snap-roundtrip-{quantized}"));
        let (ds, index, params, cfg) = build_cosine_index(&h, quantized);
        let path = snapshot_path(&dir, 400);
        save_snapshot(&index, 400, &path).unwrap();
        let (loaded, floor) = stars::serve::durable::load_snapshot(&path, &h, cfg, 2).unwrap();
        assert_eq!(floor, 400);
        assert_eq!(loaded.len(), index.len());
        let qids: Vec<u32> = (0..400).step_by(13).collect();
        let queries = ds.subset(&qids);
        let a = QueryEngine::new(index, &h, ServeMeasure::Cosine, params.clone()).workers(2);
        let b = QueryEngine::new(loaded, &h, ServeMeasure::Cosine, params.clone()).workers(2);
        assert_eq!(
            a.query(&queries, 6),
            b.query(&queries, 6),
            "loaded snapshot diverged (quantized={quantized})"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn snapshot_roundtrip_covers_the_set_feature() {
    // Weighted-Jaccard over Zipf sets: the DSET section's hybrid set
    // payload (tokens + weights) must survive the roundtrip.
    let dir = tmp_dir("snap-sets");
    let sets = synth::zipf_sets(300, &synth::ZipfSetsParams::default(), 29);
    let h = WeightedMinHash::new(3, 11);
    let params = BuildParams::threshold_mode(Algorithm::LshStars)
        .sketches(6)
        .threshold(0.1);
    let cfg = ServeConfig::default().route_reps(6).route_leaders(16).compact_limit(0);
    let (_, index) = StarsBuilder::new(&sets)
        .similarity(&WeightedJaccardSim)
        .hash(&h)
        .params(params.clone())
        .workers(2)
        .build_indexed(cfg.clone());
    let path = snapshot_path(&dir, 300);
    save_snapshot(&index, 300, &path).unwrap();
    let (loaded, _) = stars::serve::durable::load_snapshot(&path, &h, cfg, 2).unwrap();
    let qids: Vec<u32> = (0..300).step_by(17).collect();
    let queries = sets.subset(&qids);
    let a = QueryEngine::new(index, &h, ServeMeasure::WeightedJaccard, params.clone()).workers(2);
    let b = QueryEngine::new(loaded, &h, ServeMeasure::WeightedJaccard, params).workers(2);
    assert_eq!(a.query(&queries, 5), b.query(&queries, 5), "set snapshot diverged");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn snapshot_fuzz_truncation_and_bit_flips_error_with_context_never_panic() {
    let h = SimHash::new(16, 8, 7);
    let dir = tmp_dir("snap-fuzz");
    let (_, index, _, cfg) = build_cosine_index(&h, true);
    let path = snapshot_path(&dir, 400);
    save_snapshot(&index, 400, &path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    let scratch = dir.join("scratch.sss");
    // Truncation at every section boundary (plus mid-payload): loading a
    // cut file must be a contextual error, never a panic, never Ok.
    for &cut in &section_boundaries(&bytes) {
        if cut == bytes.len() {
            continue;
        }
        std::fs::write(&scratch, &bytes[..cut]).unwrap();
        let err = match stars::serve::durable::load_snapshot(&scratch, &h, cfg.clone(), 2) {
            Ok(_) => panic!("truncation at byte {cut} loaded"),
            Err(e) => e,
        };
        let msg = format!("{err:#}");
        assert!(
            msg.contains("scratch.sss"),
            "truncation at {cut}: error lost the file context: {msg}"
        );
    }
    // One flipped bit inside every section (header, tag, len, crc, and the
    // middle of each payload): per-section error context, no panic. A flip
    // can land in ignorable slack only if sections were unchecked — they
    // aren't, every payload is CRC'd.
    for &at in &section_boundaries(&bytes) {
        if at >= bytes.len() {
            continue;
        }
        let mut mutated = bytes.clone();
        mutated[at] ^= 0x40;
        std::fs::write(&scratch, &mutated).unwrap();
        let err = match stars::serve::durable::load_snapshot(&scratch, &h, cfg.clone(), 2) {
            Ok(_) => panic!("bit flip at byte {at} loaded"),
            Err(e) => e,
        };
        let msg = format!("{err:#}");
        assert!(
            msg.contains("scratch.sss") || msg.contains("section"),
            "flip at {at}: error lost its context: {msg}"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------- crash-recovery battery

/// The tentpole contract. For one tier: build once, derive the uncrashed
/// reference answers, then for a crash after every possible number of
/// WAL'd inserts (each non-final crash also tearing the next record
/// mid-append), recover and require the final top-k — after replay plus
/// the remainder of the schedule — to be bit-identical to the reference,
/// across worker counts and through the sharded engine.
fn crash_recovery_battery(quantized: bool) {
    let h = SimHash::new(16, 8, 7);
    let (ds, index, params, cfg) = build_cosine_index(&h, quantized);
    let schedule: Vec<usize> = (0..12).map(|i| (i * 31) % 400).collect();
    let qids: Vec<u32> = (0..400).step_by(13).collect();
    let queries = ds.subset(&qids);
    let reference = QueryEngine::new(index, &h, ServeMeasure::Cosine, params.clone()).workers(2);
    // Checkpoint the pristine build before feeding the reference engine
    // (inserts land in its delta, not its snapshot, so the order is
    // immaterial — but this mirrors the serve loop).
    let template = tmp_dir(&format!("crash-template-{quantized}"));
    {
        let mut store = DurableStore::open(&template, FsyncPolicy::EveryN(3)).unwrap();
        store.checkpoint(&reference.snapshot()).unwrap();
    }
    for &src in &schedule {
        reference.insert(Some(ds.row(src)), None);
    }
    let want = reference.query(&queries, 6);

    for crash_at in 0..=schedule.len() {
        // Stage the crashed state dir: the pristine snapshot, `crash_at`
        // complete WAL records, and (for non-final crash points) a torn
        // append of the next record — the crash landed mid-write().
        let dir = tmp_dir(&format!("crash-{quantized}-{crash_at}"));
        std::fs::copy(snapshot_path(&template, 400), snapshot_path(&dir, 400)).unwrap();
        let mut store = DurableStore::open(&dir, FsyncPolicy::EveryN(3)).unwrap();
        let rec = store
            .recover(&h, cfg.clone(), 2)
            .unwrap()
            .expect("template snapshot");
        assert!(rec.replay.is_empty());
        for (i, &src) in schedule[..crash_at].iter().enumerate() {
            store.log_insert(400 + i as u32, Some(ds.row(src)), None).unwrap();
        }
        if crash_at < schedule.len() {
            let keep = 1 + (crash_at * 5) % 24;
            store
                .log_torn(400 + crash_at as u32, Some(ds.row(schedule[crash_at])), None, keep)
                .unwrap();
        }
        drop(store); // the simulated crash: no checkpoint, no clean close

        for workers in [1usize, 3] {
            for sharded in [false, true] {
                let mut rstore = DurableStore::open(&dir, FsyncPolicy::EveryN(3)).unwrap();
                let rec = rstore
                    .recover(&h, cfg.clone(), workers)
                    .unwrap()
                    .expect("snapshot survived the crash");
                assert_eq!(
                    rec.replay.len(),
                    crash_at,
                    "crash@{crash_at}: wrong replay suffix (torn tail not truncated?)"
                );
                assert_eq!(rec.index.len(), 400);
                let got = if sharded {
                    let eng = ShardedEngine::new(
                        ShardedIndex::new(rec.index, 3),
                        &h,
                        ServeMeasure::Cosine,
                        params.clone(),
                    )
                    .workers(workers);
                    for r in &rec.replay {
                        assert_eq!(r.gid, eng.next_gid(), "replay out of gid order");
                        eng.insert(r.row.as_deref(), r.set.clone());
                    }
                    for &src in &schedule[rec.replay.len()..] {
                        eng.insert(Some(ds.row(src)), None);
                    }
                    eng.query(&queries, 6)
                } else {
                    let eng = QueryEngine::new(rec.index, &h, ServeMeasure::Cosine, params.clone())
                        .workers(workers);
                    for r in &rec.replay {
                        assert_eq!(r.gid, eng.next_gid(), "replay out of gid order");
                        eng.insert(r.row.as_deref(), r.set.clone());
                    }
                    for &src in &schedule[rec.replay.len()..] {
                        eng.insert(Some(ds.row(src)), None);
                    }
                    eng.query(&queries, 6)
                };
                assert_eq!(
                    got, want,
                    "crash@{crash_at} quantized={quantized} workers={workers} \
                     sharded={sharded}: recovery diverged from the uncrashed engine"
                );
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
    let _ = std::fs::remove_dir_all(&template);
}

#[test]
fn crash_recovery_is_bit_identical_exact_tier() {
    crash_recovery_battery(false);
}

#[test]
fn crash_recovery_is_bit_identical_quantized_tier() {
    crash_recovery_battery(true);
}

// ------------------------------------------------------------- store layer

#[test]
fn recovery_ignores_tmp_garbage_and_falls_back_past_a_corrupt_snapshot() {
    let h = SimHash::new(16, 8, 7);
    let dir = tmp_dir("fallback");
    let (ds, index, params, cfg) = build_cosine_index(&h, false);
    // Stash the floor-400 generation aside: checkpoint prunes superseded
    // snapshots, but a crash between publish and prune legitimately leaves
    // the older file behind — that state is restaged below.
    let side = dir.join("gen-400.keep");
    save_snapshot(&index, 400, &side).unwrap();
    let engine = QueryEngine::new(index, &h, ServeMeasure::Cosine, params).workers(2);
    let mut store = DurableStore::open(&dir, FsyncPolicy::Os).unwrap();
    store.checkpoint(&engine.snapshot()).unwrap();
    // Five durable inserts, then a compaction + second checkpoint advance
    // the durable floor to 405.
    for i in 0..5u32 {
        let row = ds.row(i as usize * 17);
        store.log_insert(400 + i, Some(row), None).unwrap();
        engine.insert(Some(row), None);
    }
    engine.compact_report().expect("delta pending");
    store.checkpoint(&engine.snapshot()).unwrap();
    drop(store);
    std::fs::copy(&side, snapshot_path(&dir, 400)).unwrap();
    assert!(snapshot_path(&dir, 400).exists());
    assert!(snapshot_path(&dir, 405).exists());
    // Crash-at-publish-boundary debris plus unrelated junk: all ignored.
    std::fs::write(dir.join("snapshot-999.sss.tmp"), b"half-published garbage").unwrap();
    std::fs::write(wal_path(&dir, 999).with_extension("log.tmp"), b"torn rotation").unwrap();
    std::fs::write(dir.join("notes.txt"), b"not ours").unwrap();
    let mut rstore = DurableStore::open(&dir, FsyncPolicy::Os).unwrap();
    let rec = rstore.recover(&h, cfg.clone(), 2).unwrap().expect("snapshot");
    assert_eq!(rec.index.len(), 405, "newest valid snapshot not selected");
    assert!(rec.replay.is_empty());
    drop(rstore);
    // Now rot the newest snapshot on disk: recovery must report it and
    // fall back to the older valid generation instead of failing.
    let newest = snapshot_path(&dir, 405);
    let mut bytes = std::fs::read(&newest).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x01;
    std::fs::write(&newest, &bytes).unwrap();
    let mut fstore = DurableStore::open(&dir, FsyncPolicy::Os).unwrap();
    let rec = fstore.recover(&h, cfg, 2).unwrap().expect("older snapshot");
    assert_eq!(rec.index.len(), 400, "fallback skipped the older valid snapshot");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn checkpoint_retains_the_wal_suffix_across_repeated_recoveries() {
    // Sequencer high-water monotonicity through the store: log, recover,
    // log more through the rotated WAL, recover again — the replay suffix
    // accumulates gaplessly and in gid order.
    let h = SimHash::new(16, 8, 7);
    let dir = tmp_dir("suffix");
    let (ds, index, params, cfg) = build_cosine_index(&h, false);
    let engine = QueryEngine::new(index, &h, ServeMeasure::Cosine, params).workers(2);
    {
        let mut store = DurableStore::open(&dir, FsyncPolicy::Always).unwrap();
        store.checkpoint(&engine.snapshot()).unwrap();
        for i in 0..6u32 {
            store.log_insert(400 + i, Some(ds.row(i as usize)), None).unwrap();
        }
    }
    let mut store = DurableStore::open(&dir, FsyncPolicy::Always).unwrap();
    let rec = store.recover(&h, cfg.clone(), 2).unwrap().expect("snapshot");
    assert_eq!(rec.replay.len(), 6);
    // The rotated WAL is live: keep logging where the suffix left off.
    for i in 6..11u32 {
        store.log_insert(400 + i, Some(ds.row(i as usize)), None).unwrap();
    }
    drop(store);
    let mut store = DurableStore::open(&dir, FsyncPolicy::Always).unwrap();
    let rec = store.recover(&h, cfg, 2).unwrap().expect("snapshot");
    assert_eq!(rec.replay.len(), 11, "rotation dropped part of the suffix");
    for (i, r) in rec.replay.iter().enumerate() {
        assert_eq!(r.gid, 400 + i as u32, "suffix out of gid order");
    }
    // The final recovery rotated the full 11-record suffix to a fresh WAL
    // at the recovered high-water (411).
    let (on_disk, torn) = read_wal(&wal_path(&dir, 411)).unwrap();
    assert_eq!(on_disk.len(), 11);
    assert_eq!(torn, 0);
    let _ = std::fs::remove_dir_all(&dir);
}
