//! Quantized-tier acceptance tests: the SQ8 round-trip bound, int8-kernel
//! bit-parity across every reachable SIMD backend (the instruction-set
//! invariance contract extended to the integer kernels — where it is in
//! fact *integer exactness*, stronger than f32 bit-identity), and the
//! rescore-restores-exact-ranking property of the two-pass serve path.
//!
//! Like `simd_parity.rs`, `scripts/ci.sh` runs this suite twice — default
//! dispatch and `STARS_SIMD=scalar` — so the dispatched int8 entry points
//! are validated under both resolutions.

use stars::data::synth;
use stars::lsh::SimHash;
use stars::serve::{QueryEngine, ServeConfig, ServeMeasure, StarIndex};
use stars::sim::quant::{dequantize_into, quantize_row, QuantDataset};
use stars::sim::CosineSim;
use stars::stars::{Algorithm, BuildParams, StarsBuilder};
use stars::util::rng::Rng;
use stars::util::simd::{self, SimdBackend};

const DIMS: [usize; 5] = [3, 8, 16, 100, 784];

fn rows(n: usize, d: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n * d).map(|_| rng.gaussian() as f32).collect()
}

/// Random i8 codes in the quantizer's emitted range `[-127, 127]`.
fn codes(n: usize, seed: u64) -> Vec<i8> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| ((rng.next_u64() % 255) as i32 - 127) as i8)
        .collect()
}

#[test]
fn round_trip_error_is_bounded_per_row() {
    // |x − deq(q(x))| ≤ scale/2 per element, scale = max|x|/127 — the
    // quantizer's advertised bound, over the acceptance dimension sweep
    // and a scale sweep (tiny to huge magnitudes).
    for &d in &DIMS {
        for (mag, seed) in [(1e-3f32, 5u64), (1.0, 6), (1e4, 7)] {
            let row: Vec<f32> = rows(1, d, seed + d as u64).iter().map(|x| x * mag).collect();
            let mut q = vec![0i8; d];
            let scale = quantize_row(&row, &mut q);
            assert!(q.iter().all(|&c| (-127..=127).contains(&(c as i32))));
            let mut back = vec![0f32; d];
            dequantize_into(&q, scale, &mut back);
            let max_abs = row.iter().fold(0f32, |m, x| m.max(x.abs()));
            assert!((scale - max_abs / 127.0).abs() <= max_abs * 1e-6);
            for k in 0..d {
                assert!(
                    (row[k] - back[k]).abs() <= scale * 0.5 * (1.0 + 1e-5),
                    "d={d} mag={mag} k={k}: {} vs {} (scale {scale})",
                    row[k],
                    back[k]
                );
            }
        }
    }
}

#[test]
fn forced_override_applies_to_int8_kernels() {
    // resolve() governs the int8 entry points exactly like the f32 ones:
    // under STARS_SIMD=..., dot_i8 must equal the forced backend's _with.
    assert_eq!(simd::resolve(Some("scalar")), SimdBackend::Scalar);
    let a = codes(100, 3);
    let b = codes(100, 4);
    assert_eq!(simd::dot_i8(&a, &b), simd::dot_i8_with(simd::active(), &a, &b));
    if let Ok(forced) = std::env::var(simd::SIMD_ENV) {
        let want = match SimdBackend::parse(&forced) {
            Some(b) if simd::supported(b) => b,
            Some(_) => SimdBackend::Scalar,
            None => simd::detected(),
        };
        assert_eq!(simd::active(), want, "STARS_SIMD={forced} not honored");
    }
}

#[test]
fn int8_kernels_integer_exact_across_backends() {
    // i32 accumulation is associative: every backend returns the *same
    // integer*, not merely the same bits of a rounding-tolerant float.
    for backend in simd::reachable() {
        for &d in &DIMS {
            let a = codes(d, 11 + d as u64);
            let b = codes(d, 77 + d as u64);
            assert_eq!(
                simd::dot_i8_with(backend, &a, &b),
                simd::dot_i8_with(SimdBackend::Scalar, &a, &b),
                "dot_i8 {backend:?} d={d}"
            );
            let t = codes(4 * d, 5 + d as u64);
            let (t0, t1, t2, t3) = (&t[..d], &t[d..2 * d], &t[2 * d..3 * d], &t[3 * d..4 * d]);
            assert_eq!(
                simd::dot_i8_block4_with(backend, &a, t0, t1, t2, t3),
                simd::dot_i8_block4_with(SimdBackend::Scalar, &a, t0, t1, t2, t3),
                "dot_i8_block4 {backend:?} d={d}"
            );
        }
    }
}

#[test]
fn quant_estimates_bit_identical_across_backends() {
    // One level up: the full estimate (integer dot × two float scales) is
    // bit-identical per backend because the float part is two multiplies
    // in a fixed order.
    let ds = synth::gaussian_mixture(64, 100, 4, 0.2, 9);
    let q = QuantDataset::from_dataset(&ds);
    let mut qc = vec![0i8; ds.dim()];
    let qs = quantize_row(ds.row(3), &mut qc);
    let cands: Vec<u32> = (0..64).collect();
    let mut want = Vec::new();
    q.dot_estimates_with(SimdBackend::Scalar, &qc, qs, &cands, &mut want);
    for backend in simd::reachable() {
        let mut got = Vec::new();
        q.dot_estimates_with(backend, &qc, qs, &cands, &mut got);
        for j in 0..cands.len() {
            assert_eq!(
                got[j].to_bits(),
                want[j].to_bits(),
                "estimate {backend:?} cand {j}"
            );
        }
    }
}

#[test]
fn wide_rescore_restores_the_exact_ranking() {
    // With rescore_factor wide enough that every first-pass candidate
    // survives, the quantized engine must be *bitwise* equal to the exact
    // engine — the rescore runs the same f32 kernels over the same
    // candidate set, so any divergence is a two-pass bookkeeping bug.
    let h = SimHash::new(16, 8, 7);
    let ds = synth::gaussian_mixture(1000, 16, 10, 0.08, 21);
    let params = BuildParams::threshold_mode(Algorithm::LshStars)
        .sketches(8)
        .threshold(0.4);
    let out = StarsBuilder::new(&ds)
        .similarity(&CosineSim)
        .hash(&h)
        .params(params.clone())
        .workers(2)
        .build();
    let cfg = ServeConfig::default().route_reps(8).compact_limit(0);
    let exact = QueryEngine::new(
        StarIndex::build(ds.clone(), &h, &out.graph, cfg.clone()),
        &h,
        ServeMeasure::Cosine,
        params.clone(),
    )
    .workers(2);
    let quant = QueryEngine::new(
        StarIndex::build(ds.clone(), &h, &out.graph, cfg.quantized(100_000)),
        &h,
        ServeMeasure::Cosine,
        params,
    )
    .workers(2);
    let qids: Vec<u32> = (0..1000u32).step_by(37).collect();
    let queries = ds.subset(&qids);
    assert_eq!(
        quant.query(&queries, 10),
        exact.query(&queries, 10),
        "wide rescore diverged from the exact engine"
    );
}
