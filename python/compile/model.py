"""L2: the learned pairwise similarity model (paper §C.2 / §D.3, after
Grale [24]).

Architecture (sizes scaled to this repo's Amazon2m stand-in):
  * per-side tower: concat(embedding[100], hashed co-purchase multi-hot[64])
    -> dense(100) ReLU -> dense(100) ReLU -> dense(32) linear  (shared weights)
  * pairwise head: concat(hadamard(tower_a, tower_b)[32],
                          [cosine, co-purchase indicator, jaccard][3])
    -> dense(100) ReLU -> dense(100) ReLU -> dense(1) -> sigmoid.

The dense layers run through the L1 Pallas kernel (kernels.dense), so the
AOT-lowered learned_sim artifact carries the same kernel path the scorers do.
Trained at artifact-build time on synthetic same/different-category pairs
drawn from the shared recipe (compile/recipe.py == rust data::recipe), then
frozen into HLO. Python never runs at request time.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from compile import recipe
from compile.kernels import dense as dense_kernel

# Shapes (mirrored into artifacts/meta.json; rust reads them from there).
DIM = 100           # embedding dimension
HASH_BUCKETS = 64   # co-purchase multi-hot size
PAIR_FEATS = 3      # [cosine, co-purchase indicator, jaccard]
EMB = 32            # tower output
HIDDEN = 100
BATCH = 256


def init_params(seed: int) -> dict:
    """He-initialized parameter pytree."""
    rng = np.random.default_rng(seed)

    def layer(d_in, d_out):
        w = rng.standard_normal((d_in, d_out), dtype=np.float32)
        w *= np.sqrt(2.0 / d_in).astype(np.float32)
        return {"w": jnp.asarray(w), "b": jnp.zeros((d_out,), jnp.float32)}

    tower_in = DIM + HASH_BUCKETS
    head_in = EMB + PAIR_FEATS
    return {
        "t1": layer(tower_in, HIDDEN),
        "t2": layer(HIDDEN, HIDDEN),
        "t3": layer(HIDDEN, EMB),
        "h1": layer(head_in, HIDDEN),
        "h2": layer(HIDDEN, HIDDEN),
        "h3": layer(HIDDEN, 1),
    }


def _dense(use_pallas, x, layer, relu):
    if use_pallas:
        return dense_kernel.dense(x, layer["w"], layer["b"], relu=relu)
    y = x @ layer["w"] + layer["b"][None, :]
    return jnp.maximum(y, 0.0) if relu else y


def tower(params, e, h, use_pallas=False):
    """Shared-weight embedding tower."""
    x = jnp.concatenate([e, h], axis=1)
    x = _dense(use_pallas, x, params["t1"], True)
    x = _dense(use_pallas, x, params["t2"], True)
    return _dense(use_pallas, x, params["t3"], False)


def logits(params, ea, ha, eb, hb, pf, use_pallas=False):
    """Unthresholded pairwise score (the paper's scalar output)."""
    ta = tower(params, ea, ha, use_pallas)
    tb = tower(params, eb, hb, use_pallas)
    pair = jnp.concatenate([ta * tb, pf], axis=1)  # Hadamard ++ pair feats
    x = _dense(use_pallas, pair, params["h1"], True)
    x = _dense(use_pallas, x, params["h2"], True)
    return _dense(use_pallas, x, params["h3"], False)[:, 0]


def similarity(params, ea, ha, eb, hb, pf, use_pallas=False):
    """Similarity in (0, 1): sigmoid of the logit."""
    return jax.nn.sigmoid(logits(params, ea, ha, eb, hb, pf, use_pallas))


# --------------------------------------------------------------------------
# Training-data generation from the shared recipe (distributionally identical
# to rust data::synth::products; see DESIGN.md §3).
# --------------------------------------------------------------------------

# Mirror of rust data::synth::ProductsParams::default() — keep in sync.
PRODUCTS = {
    "classes": 47,
    "noise": 0.09,
    "vocab": 20_000,
    "pool_size": 24,
    "basket": 40,
    "class_mass": 0.8,
}


class PairSampler:
    """Samples featurized (same-class? different-class?) product pairs."""

    def __init__(self, seed: int, np_seed: int):
        p = PRODUCTS
        self.means = np.asarray(
            [recipe.class_mean(seed, c, DIM) for c in range(p["classes"])],
            dtype=np.float32,
        )
        self.pools = [
            recipe.class_token_pool(seed, c, p["vocab"], p["pool_size"])
            for c in range(p["classes"])
        ]
        self.rng = np.random.default_rng(np_seed)

    def _point(self, c: int):
        p = PRODUCTS
        e = self.means[c] + p["noise"] * self.rng.standard_normal(DIM).astype(np.float32)
        pool = self.pools[c]
        toks = set()
        for _ in range(p["basket"]):
            if self.rng.random() < p["class_mass"]:
                toks.add(pool[self.rng.integers(len(pool))])
            else:
                toks.add(int(self.rng.integers(p["vocab"])))
        return e, toks

    def batch(self, size: int):
        """Featurized batch: (ea, ha, eb, hb, pf, labels)."""
        p = PRODUCTS
        ea = np.zeros((size, DIM), np.float32)
        eb = np.zeros((size, DIM), np.float32)
        ha = np.zeros((size, HASH_BUCKETS), np.float32)
        hb = np.zeros((size, HASH_BUCKETS), np.float32)
        pf = np.zeros((size, PAIR_FEATS), np.float32)
        y = np.zeros((size,), np.float32)
        for k in range(size):
            same = self.rng.random() < 0.5
            c1 = int(self.rng.integers(p["classes"]))
            c2 = c1 if same else int(self.rng.integers(p["classes"]))
            if not same and c2 == c1:
                c2 = (c1 + 1) % p["classes"]
            e1, t1 = self._point(c1)
            e2, t2 = self._point(c2)
            ea[k], eb[k] = e1, e2
            for t in t1:
                ha[k, recipe.hash_token(t, HASH_BUCKETS)] = 1.0
            for t in t2:
                hb[k, recipe.hash_token(t, HASH_BUCKETS)] = 1.0
            inter = len(t1 & t2)
            union = len(t1 | t2)
            jac = inter / union if union else 0.0
            cos = float(
                e1 @ e2 / max(np.linalg.norm(e1) * np.linalg.norm(e2), 1e-12)
            )
            pf[k] = [cos, 1.0 if inter > 0 else 0.0, jac]
            y[k] = 1.0 if c1 == c2 else 0.0
        return ea, ha, eb, hb, pf, y


# --------------------------------------------------------------------------
# Training (hand-rolled Adam; optax is not assumed present).
# --------------------------------------------------------------------------


def loss_fn(params, batch):
    ea, ha, eb, hb, pf, y = batch
    z = logits(params, ea, ha, eb, hb, pf, use_pallas=False)
    # Binary cross entropy with logits (stable form).
    return jnp.mean(jnp.maximum(z, 0.0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z))))


@functools.partial(jax.jit, donate_argnums=(0, 1, 2))
def adam_step(params, m, v, t, batch, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    loss, grads = jax.value_and_grad(loss_fn)(params, batch)
    m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g, m, grads)
    v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * g * g, v, grads)
    mhat = jax.tree.map(lambda a: a / (1 - b1**t), m)
    vhat = jax.tree.map(lambda a: a / (1 - b2**t), v)
    params = jax.tree.map(
        lambda p, a, b: p - lr * a / (jnp.sqrt(b) + eps), params, mhat, vhat
    )
    return params, m, v, loss


def train(seed: int = 42, steps: int = 400, batch_size: int = BATCH, np_seed: int = 7):
    """Train the model; returns (params, holdout_auc)."""
    params = init_params(np_seed)
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)
    sampler = PairSampler(seed, np_seed)
    for t in range(1, steps + 1):
        batch = sampler.batch(batch_size)
        params, m, v, _ = adam_step(params, m, v, float(t), batch)
    # Holdout AUC on a fresh sample (distinct numpy stream).
    holdout = PairSampler(seed, np_seed + 1).batch(2048)
    scores = np.asarray(similarity(params, *holdout[:5]))
    auc = compute_auc(scores, holdout[5])
    return params, float(auc)


def compute_auc(scores: np.ndarray, labels: np.ndarray) -> float:
    """Rank-based AUC (Mann-Whitney)."""
    order = np.argsort(scores)
    ranks = np.empty_like(order, dtype=np.float64)
    ranks[order] = np.arange(1, len(scores) + 1)
    pos = labels > 0.5
    n_pos = int(pos.sum())
    n_neg = len(labels) - n_pos
    if n_pos == 0 or n_neg == 0:
        return float("nan")
    return (ranks[pos].sum() - n_pos * (n_pos + 1) / 2) / (n_pos * n_neg)
