"""Shared deterministic recipe — python mirror of rust/src/data/recipe.rs.

The learned similarity model is trained (at artifact-build time) on synthetic
products drawn from the same class geometry the rust generators use at
evaluation time. That geometry is pinned by this module: a SplitMix64 stream
plus Box-Muller gaussians, implemented identically on both sides.

Do not change any constant here without changing the rust mirror and
regenerating artifacts. Cross-language golden values are asserted in
python/tests/test_recipe.py and rust/src/data/recipe.rs.
"""

import math

MASK64 = (1 << 64) - 1

CLASS_MEAN_STREAM = 0xC1A5
CLASS_TOKENS_STREAM = 0x70CE


class SplitMix64:
    """Mirror of rust util::rng::SplitMix64."""

    def __init__(self, seed: int):
        self.state = seed & MASK64

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
        return (z ^ (z >> 31)) & MASK64

    def next_f64(self) -> float:
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def next_gaussian(self) -> float:
        u1 = self.next_f64()
        if u1 < 1e-300:
            u1 = 1e-300
        u2 = self.next_f64()
        return math.sqrt(-2.0 * math.log(u1)) * math.cos(2.0 * math.pi * u2)


def derive_seed(parent: int, stream: int) -> int:
    """Mirror of rust util::rng::derive_seed."""
    mixed = (parent ^ ((stream * 0xA0761D6478BD642F) & MASK64)) & MASK64
    return SplitMix64(mixed).next_u64()


def class_mean(seed: int, class_id: int, dim: int) -> list[float]:
    """Unit-norm class prototype — mirror of data::recipe::class_mean."""
    sm = SplitMix64(derive_seed(seed ^ CLASS_MEAN_STREAM, class_id))
    raw = [sm.next_gaussian() for _ in range(dim)]
    norm = max(math.sqrt(sum(x * x for x in raw)), 1e-12)
    # Rust casts each f64/norm to f32; numpy float32 cast happens downstream.
    return [x / norm for x in raw]


def class_token_pool(seed: int, class_id: int, vocab: int, pool_size: int) -> list[int]:
    """Class co-purchase token pool — mirror of data::recipe::class_token_pool."""
    sm = SplitMix64(derive_seed(seed ^ CLASS_TOKENS_STREAM, class_id))
    return [sm.next_u64() % vocab for _ in range(pool_size)]


def hash_token(token: int, buckets: int) -> int:
    """Knuth multiplicative co-purchase hash — mirror of runtime::learned::hash_token."""
    return ((token * 2654435761) & 0xFFFFFFFF) % buckets
