"""Pure-jnp correctness oracles for the Pallas kernels.

pytest (python/tests/test_kernel.py) asserts allclose between each kernel and
its oracle across shapes and seeds — the core L1 correctness signal.
"""

import jax.numpy as jnp


def cosine_scores_ref(leaders, cands):
    """Reference for kernels.pairwise.cosine_scores."""
    dots = leaders @ cands.T
    lnorm = jnp.linalg.norm(leaders, axis=1, keepdims=True)
    cnorm = jnp.linalg.norm(cands, axis=1, keepdims=True).T
    denom = lnorm * cnorm
    return jnp.where(denom > 0.0, dots / denom, 0.0)


def simhash_bits_ref(x, g):
    """Reference for kernels.simhash.simhash_bits."""
    return (x @ g >= 0.0).astype(jnp.float32)


def dense_ref(x, w, b, relu=True):
    """Reference for kernels.dense.dense."""
    y = x @ w + b[None, :]
    return jnp.maximum(y, 0.0) if relu else y
