"""L1 Pallas kernel: tiled leaders x block cosine scoring.

The Stars scoring hot-spot is "compare one leader against every bucket
member". Batched over L leaders and B candidates this is a small matmul with
row normalization — an MXU-shaped computation.

TPU mapping (DESIGN.md §Hardware-Adaptation): the (L, D) leader tile and a
(BT, D) candidate tile live in VMEM; the grid streams candidate tiles
HBM->VMEM (the BlockSpec index_map below), and each grid step is one
(L x D) @ (D x BT) MXU matmul plus a VPU rsqrt row-scale. With L=8, BT=128,
D=128 the working set is ~200 KiB — far under the 16 MiB VMEM budget, so the
pipeline can double-buffer deeply.

On this image Pallas must run interpret=True (CPU PJRT cannot execute Mosaic
custom-calls); correctness is pinned against kernels/ref.py by pytest.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Candidate-tile width. 128 = one MXU/VPU lane width.
BLOCK_B = 128


def _cosine_kernel(leaders_ref, cands_ref, out_ref):
    """One grid step: score all leaders against one candidate tile."""
    lead = leaders_ref[...]  # (L, D) — resident across the grid
    cand = cands_ref[...]  # (BT, D) — streamed per grid step
    # MXU: (L, D) @ (D, BT).
    dots = jnp.dot(lead, cand.T, preferred_element_type=jnp.float32)
    # VPU: row/col inverse norms (guarding zero-padded rows).
    lnorm = jnp.sum(lead * lead, axis=1, keepdims=True)  # (L, 1)
    cnorm = jnp.sum(cand * cand, axis=1, keepdims=True).T  # (1, BT)
    denom = jnp.sqrt(lnorm * cnorm)
    out_ref[...] = jnp.where(denom > 0.0, dots / denom, 0.0)


@functools.partial(jax.jit, static_argnames=())
def cosine_scores(leaders, cands):
    """Cosine similarity of every leader row against every candidate row.

    leaders: (L, D) f32, cands: (B, D) f32 with B % BLOCK_B == 0.
    Returns (L, B) f32 in [-1, 1] (0 where either row is all-zero padding).
    """
    l, d = leaders.shape
    b, d2 = cands.shape
    assert d == d2, f"dim mismatch {d} vs {d2}"
    assert b % BLOCK_B == 0, f"candidate count {b} not a multiple of {BLOCK_B}"
    grid = (b // BLOCK_B,)
    return pl.pallas_call(
        _cosine_kernel,
        grid=grid,
        in_specs=[
            # Leaders: same full tile at every grid step (resident in VMEM).
            pl.BlockSpec((l, d), lambda i: (0, 0)),
            # Candidates: stream one BLOCK_B-row tile per grid step.
            pl.BlockSpec((BLOCK_B, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((l, BLOCK_B), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((l, b), jnp.float32),
        interpret=True,
    )(leaders, cands)
