"""L1 Pallas kernel: fused dense layer (x @ W + b, optional ReLU).

The learned similarity model's towers and pairwise MLP are stacks of these.
Keeping the layer as a Pallas kernel means the learned_sim artifact's hot
FLOPs flow through the same kernel layer as the scorers: one (BT, IN) @
(IN, OUT) MXU matmul per grid step with the bias add and ReLU fused on the
VPU before writeback.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 128


def _dense_kernel(x_ref, w_ref, b_ref, out_ref, *, relu: bool):
    x = x_ref[...]
    w = w_ref[...]
    bias = b_ref[...]
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + bias[None, :]
    if relu:
        y = jnp.maximum(y, 0.0)
    out_ref[...] = y


@functools.partial(jax.jit, static_argnames=("relu",))
def dense(x, w, b, relu: bool = True):
    """Fused dense layer. x: (B, IN), w: (IN, OUT), b: (OUT,). B % 128 == 0."""
    batch, d_in = x.shape
    d_in2, d_out = w.shape
    assert d_in == d_in2 and b.shape == (d_out,)
    assert batch % BLOCK_ROWS == 0, f"batch {batch} not a multiple of {BLOCK_ROWS}"
    grid = (batch // BLOCK_ROWS,)
    kernel = functools.partial(_dense_kernel, relu=relu)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, d_in), lambda i: (i, 0)),
            pl.BlockSpec((d_in, d_out), lambda i: (0, 0)),
            pl.BlockSpec((d_out,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, d_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, d_out), jnp.float32),
        interpret=True,
    )(x, w, b)
