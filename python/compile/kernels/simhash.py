"""L1 Pallas kernel: SimHash sketching (sign bits of X @ G).

One grid step sketches a tile of points against the full hyperplane matrix G
(baked as a compile-time constant from a seed): an (BT, D) @ (D, M) MXU
matmul followed by a VPU sign. Output is 0/1 f32; the rust side packs bits.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

BLOCK_ROWS = 128


def hyperplanes(seed: int, dim: int, bits: int) -> np.ndarray:
    """Deterministic (dim, bits) gaussian hyperplane matrix."""
    rng = np.random.default_rng(seed)
    return rng.standard_normal((dim, bits), dtype=np.float32)


def _simhash_kernel(x_ref, g_ref, out_ref):
    x = x_ref[...]  # (BT, D)
    g = g_ref[...]  # (D, M) resident
    dots = jnp.dot(x, g, preferred_element_type=jnp.float32)
    out_ref[...] = (dots >= 0.0).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=())
def simhash_bits(x, g):
    """Sign bits (0/1 f32) of x @ g.

    x: (B, D) f32 with B % BLOCK_ROWS == 0; g: (D, M) f32. Returns (B, M).
    """
    b, d = x.shape
    d2, m = g.shape
    assert d == d2
    assert b % BLOCK_ROWS == 0
    grid = (b // BLOCK_ROWS,)
    return pl.pallas_call(
        _simhash_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, d), lambda i: (i, 0)),
            pl.BlockSpec((d, m), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, m), jnp.float32),
        interpret=True,
    )(x, g)
