"""AOT compile path: lower every artifact to HLO **text** + meta.json.

Run once via `make artifacts`; the rust runtime (rust/src/runtime/) loads the
text with `HloModuleProto::from_text_file` and executes via PJRT. Python is
never on the request path.

HLO text — NOT `.serialize()` — is the interchange format: jax >= 0.5 emits
protos with 64-bit instruction ids that the image's xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Artifacts:
  cosine_scorer.hlo.txt   (leaders[8,128], cands[512,128]) -> scores[8,512]
  simhash_sketch.hlo.txt  (x[256,128]) -> bits[256,64]   (G baked constant)
  learned_sim.hlo.txt     (ea, ha, eb, hb, pf)[256,...]  -> sim[256]
  meta.json               shapes + file names + training AUC
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as model_mod
from compile.kernels import pairwise, simhash

# Artifact shapes (rust reads these from meta.json; keep in sync with tests).
SCORER_LEADERS = 8
SCORER_BLOCK = 512
SCORER_DIM = 128
SKETCH_BLOCK = 256
SKETCH_DIM = 128
SKETCH_BITS = 64
SKETCH_SEED = 0x5EED


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser).

    `print_large_constants=True` is load-bearing: the default printer elides
    big constants as `{...}`, which the 0.5.1 text parser silently fills
    with zeros — wiping out the frozen model weights / hyperplanes.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    text = comp.as_hlo_text(print_large_constants=True)
    assert "{...}" not in text, "HLO printer elided constants"
    return text


def build_cosine_scorer(out_dir: str) -> dict:
    spec = jax.ShapeDtypeStruct
    lowered = jax.jit(pairwise.cosine_scores).lower(
        spec((SCORER_LEADERS, SCORER_DIM), jnp.float32),
        spec((SCORER_BLOCK, SCORER_DIM), jnp.float32),
    )
    path = os.path.join(out_dir, "cosine_scorer.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    return {
        "file": "cosine_scorer.hlo.txt",
        "leaders": SCORER_LEADERS,
        "block": SCORER_BLOCK,
        "dim": SCORER_DIM,
    }


def build_simhash_sketch(out_dir: str) -> dict:
    g = jnp.asarray(simhash.hyperplanes(SKETCH_SEED, SKETCH_DIM, SKETCH_BITS))

    def sketch(x):
        return simhash.simhash_bits(x, g)

    lowered = jax.jit(sketch).lower(
        jax.ShapeDtypeStruct((SKETCH_BLOCK, SKETCH_DIM), jnp.float32)
    )
    path = os.path.join(out_dir, "simhash_sketch.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    return {
        "file": "simhash_sketch.hlo.txt",
        "block": SKETCH_BLOCK,
        "dim": SKETCH_DIM,
        "bits": SKETCH_BITS,
        "seed": SKETCH_SEED,
    }


def build_learned_sim(out_dir: str, steps: int, seed: int) -> dict:
    t0 = time.time()
    params, auc = model_mod.train(seed=seed, steps=steps)
    train_secs = time.time() - t0

    def fwd(ea, ha, eb, hb, pf):
        # The frozen model: params closed over as constants; dense layers run
        # through the Pallas kernel so the artifact exercises the L1 path.
        return model_mod.similarity(params, ea, ha, eb, hb, pf, use_pallas=True)

    spec = jax.ShapeDtypeStruct
    b = model_mod.BATCH
    lowered = jax.jit(fwd).lower(
        spec((b, model_mod.DIM), jnp.float32),
        spec((b, model_mod.HASH_BUCKETS), jnp.float32),
        spec((b, model_mod.DIM), jnp.float32),
        spec((b, model_mod.HASH_BUCKETS), jnp.float32),
        spec((b, model_mod.PAIR_FEATS), jnp.float32),
    )
    path = os.path.join(out_dir, "learned_sim.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))

    # Golden I/O for the rust runtime smoke test: a fixed batch plus the
    # model's scores, so rust can verify end-to-end numerics after loading.
    # Format: little-endian u64 section count, then per section u64 length +
    # f32 data, in order [ea, ha, eb, hb, pf, scores].
    sampler = model_mod.PairSampler(seed, 1234)
    ea, ha, eb, hb, pf, y = sampler.batch(b)
    scores = np.asarray(fwd(ea, ha, eb, hb, pf))
    sections = [ea, ha, eb, hb, pf, scores]
    with open(os.path.join(out_dir, "learned_sim_golden.bin"), "wb") as f:
        f.write(np.uint64(len(sections)).tobytes())
        for arr in sections:
            flat = np.ascontiguousarray(arr, dtype=np.float32).reshape(-1)
            f.write(np.uint64(flat.size).tobytes())
            f.write(flat.tobytes())

    return {
        "file": "learned_sim.hlo.txt",
        "batch": b,
        "dim": model_mod.DIM,
        "hash_buckets": model_mod.HASH_BUCKETS,
        "pair_feats": model_mod.PAIR_FEATS,
        "auc": auc,
        "train_steps": steps,
        "train_secs": round(train_secs, 2),
        "recipe_seed": seed,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--steps", type=int, default=400, help="model training steps")
    ap.add_argument("--seed", type=int, default=42, help="shared recipe seed")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    meta = {"recipe_seed": args.seed}
    print("[aot] lowering cosine_scorer ...")
    meta["cosine_scorer"] = build_cosine_scorer(args.out)
    print("[aot] lowering simhash_sketch ...")
    meta["simhash_sketch"] = build_simhash_sketch(args.out)
    print(f"[aot] training learned_sim ({args.steps} steps) ...")
    meta["learned_sim"] = build_learned_sim(args.out, args.steps, args.seed)
    print(f"[aot] learned_sim holdout AUC = {meta['learned_sim']['auc']:.4f}")

    with open(os.path.join(args.out, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    print(f"[aot] wrote artifacts to {args.out}")


if __name__ == "__main__":
    main()
