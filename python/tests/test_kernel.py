"""L1 kernels vs pure-jnp oracles — the core correctness signal.

Hypothesis sweeps shapes and value distributions; every Pallas kernel must
match its ref.py oracle to float32 tolerance.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dense, pairwise, ref, simhash

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(scale * rng.standard_normal(shape).astype(np.float32))


# ---------------------------------------------------------------- pairwise


@given(
    l=st.sampled_from([1, 3, 8]),
    b_tiles=st.integers(1, 4),
    d=st.sampled_from([4, 100, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_cosine_matches_ref(l, b_tiles, d, seed):
    leaders = rand((l, d), seed)
    cands = rand((b_tiles * pairwise.BLOCK_B, d), seed + 1)
    got = pairwise.cosine_scores(leaders, cands)
    want = ref.cosine_scores_ref(leaders, cands)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_cosine_zero_rows_give_zero():
    leaders = jnp.zeros((8, 16), jnp.float32)
    cands = rand((pairwise.BLOCK_B, 16), 3)
    got = pairwise.cosine_scores(leaders, cands)
    assert np.all(np.asarray(got) == 0.0)


def test_cosine_self_similarity_is_one():
    x = rand((8, 128), 5)
    cands = jnp.concatenate([x, jnp.zeros((pairwise.BLOCK_B - 8, 128))], axis=0)
    got = np.asarray(pairwise.cosine_scores(x, cands))
    np.testing.assert_allclose(np.diag(got[:, :8]), 1.0, atol=1e-5)


def test_cosine_range_bounded():
    got = np.asarray(pairwise.cosine_scores(rand((4, 32), 9), rand((128, 32), 10)))
    assert got.min() >= -1.0 - 1e-5 and got.max() <= 1.0 + 1e-5


def test_cosine_rejects_ragged_block():
    with pytest.raises(AssertionError):
        pairwise.cosine_scores(rand((4, 16), 1), rand((100, 16), 2))


# ---------------------------------------------------------------- simhash


@given(
    tiles=st.integers(1, 3),
    d=st.sampled_from([8, 64, 128]),
    m=st.sampled_from([12, 30, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_simhash_matches_ref(tiles, d, m, seed):
    x = rand((tiles * simhash.BLOCK_ROWS, d), seed)
    g = jnp.asarray(simhash.hyperplanes(seed + 1, d, m))
    got = simhash.simhash_bits(x, g)
    want = ref.simhash_bits_ref(x, g)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_simhash_bits_are_binary():
    x = rand((simhash.BLOCK_ROWS, 32), 2)
    g = jnp.asarray(simhash.hyperplanes(3, 32, 16))
    got = np.asarray(simhash.simhash_bits(x, g))
    assert set(np.unique(got)).issubset({0.0, 1.0})


def test_simhash_identical_rows_identical_bits():
    row = rand((1, 64), 4)
    x = jnp.tile(row, (simhash.BLOCK_ROWS, 1))
    g = jnp.asarray(simhash.hyperplanes(5, 64, 24))
    got = np.asarray(simhash.simhash_bits(x, g))
    assert (got == got[0]).all()


def test_hyperplanes_deterministic():
    a = simhash.hyperplanes(7, 16, 8)
    b = simhash.hyperplanes(7, 16, 8)
    np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------- dense


@given(
    tiles=st.integers(1, 2),
    d_in=st.sampled_from([35, 100, 164]),
    d_out=st.sampled_from([1, 32, 100]),
    relu=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_matches_ref(tiles, d_in, d_out, relu, seed):
    x = rand((tiles * dense.BLOCK_ROWS, d_in), seed)
    w = rand((d_in, d_out), seed + 1, scale=0.1)
    b = rand((d_out,), seed + 2)
    got = dense.dense(x, w, b, relu=relu)
    want = ref.dense_ref(x, w, b, relu=relu)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_dense_relu_clamps():
    x = rand((dense.BLOCK_ROWS, 8), 1)
    w = rand((8, 4), 2)
    b = jnp.asarray(np.full((4,), -100.0, np.float32))
    got = np.asarray(dense.dense(x, w, b, relu=True))
    assert got.min() >= 0.0
