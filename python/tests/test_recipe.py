"""Cross-language recipe checks: python mirror vs rust golden values.

The rust side asserts the same constants (rust/src/util/rng.rs and
rust/src/data/recipe.rs); the goldens below were captured from the rust
implementation (examples/quickstart.rs dump) and pin the bridge.
"""

import math

import pytest

from compile import recipe


def test_splitmix_reference_values():
    sm = recipe.SplitMix64(0)
    assert sm.next_u64() == 0xE220A8397B1DCDAF
    assert sm.next_u64() == 0x6E789E6AA1B965F4
    assert sm.next_u64() == 0x06C45D188009454F


def test_class_mean_matches_rust_golden():
    got = recipe.class_mean(42, 0, 8)
    want = [
        0.11108279,
        0.12884913,
        -0.5187552,
        0.47085604,
        0.45231187,
        -0.06786341,
        -0.49378076,
        -0.16503093,
    ]
    assert len(got) == 8
    for g, w in zip(got, want):
        assert g == pytest.approx(w, abs=1e-6)


def test_class_token_pool_matches_rust_golden():
    got = recipe.class_token_pool(42, 0, 1000, 8)
    assert got == [939, 875, 270, 440, 480, 816, 121, 421]


def test_class_mean_unit_norm():
    for c in range(5):
        m = recipe.class_mean(7, c, 100)
        assert math.sqrt(sum(x * x for x in m)) == pytest.approx(1.0, abs=1e-9)


def test_distinct_classes_decorrelated():
    a = recipe.class_mean(7, 0, 100)
    b = recipe.class_mean(7, 1, 100)
    dot = sum(x * y for x, y in zip(a, b))
    assert abs(dot) < 0.5


def test_hash_token_range():
    for t in [0, 1, 17, 9999, 2**32 - 1]:
        h = recipe.hash_token(t, 64)
        assert 0 <= h < 64
    # Spot value consistent with the rust implementation:
    # (17 * 2654435761) mod 2^32 mod 64
    assert recipe.hash_token(17, 64) == ((17 * 2654435761) % (2**32)) % 64
