"""L2 model tests: shapes, pallas-vs-plain forward equivalence, learnability."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


@pytest.fixture(scope="module")
def params():
    return model.init_params(0)


@pytest.fixture(scope="module")
def batch():
    return model.PairSampler(42, 99).batch(model.BATCH)


def test_forward_shapes(params, batch):
    ea, ha, eb, hb, pf, _ = batch
    z = model.logits(params, ea, ha, eb, hb, pf)
    assert z.shape == (model.BATCH,)
    s = model.similarity(params, ea, ha, eb, hb, pf)
    assert s.shape == (model.BATCH,)
    assert float(jnp.min(s)) > 0.0 and float(jnp.max(s)) < 1.0


def test_pallas_and_plain_forward_agree(params, batch):
    """The lowered artifact uses the Pallas dense kernel; training used the
    plain path. They must agree to float tolerance."""
    ea, ha, eb, hb, pf, _ = batch
    plain = model.similarity(params, ea, ha, eb, hb, pf, use_pallas=False)
    pallas = model.similarity(params, ea, ha, eb, hb, pf, use_pallas=True)
    np.testing.assert_allclose(np.asarray(plain), np.asarray(pallas), rtol=1e-4, atol=1e-5)


def test_symmetric_inputs_score_equal(params, batch):
    ea, ha, eb, hb, pf, _ = batch
    # Identical sides -> towers identical; the model is symmetric in (a, b)
    # because the pair representation (hadamard) is commutative.
    s1 = model.similarity(params, ea, ha, eb, hb, pf)
    s2 = model.similarity(params, eb, hb, ea, ha, pf)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5, atol=1e-6)


def test_training_learns_category_signal():
    params, auc = model.train(seed=42, steps=60, np_seed=3)
    assert 0.75 < auc <= 1.0, f"AUC after 60 steps: {auc}"


def test_trained_model_separates_same_vs_diff():
    params, _ = model.train(seed=42, steps=60, np_seed=3)
    ea, ha, eb, hb, pf, y = model.PairSampler(42, 55).batch(512)
    s = np.asarray(model.similarity(params, ea, ha, eb, hb, pf))
    same = s[y > 0.5].mean()
    diff = s[y < 0.5].mean()
    assert same > diff + 0.2, f"same {same} vs diff {diff}"


def test_auc_of_random_scores_is_half():
    rng = np.random.default_rng(0)
    scores = rng.random(4000)
    labels = (rng.random(4000) > 0.5).astype(np.float32)
    auc = model.compute_auc(scores, labels)
    assert abs(auc - 0.5) < 0.05


def test_auc_of_perfect_scores_is_one():
    labels = np.array([0, 0, 1, 1], np.float32)
    scores = np.array([0.1, 0.2, 0.8, 0.9])
    assert model.compute_auc(scores, labels) == 1.0
