#!/usr/bin/env bash
# CI entry point: tier-1 (build + tests) plus formatting, lint and rustdoc
# gates.
#
#   scripts/ci.sh          # tier-1 + fmt + clippy + rustdoc + bench compile
#   scripts/ci.sh --bench  # also regenerate BENCH_scoring.json,
#                          # BENCH_sketch.json and BENCH_serve.json (slow)
#
# The perf trajectory is tracked via BENCH_scoring.json, BENCH_sketch.json
# and BENCH_serve.json at the repo root, emitted by `cargo bench --bench
# microbench`, `--bench sketchbench` and `--bench servebench` (see
# EXPERIMENTS.md §Perf / §Serve). Benches are always *compiled*
# (`cargo bench --no-run`, which covers servebench too) so bench code cannot
# rot between the occasional timed runs.
set -euo pipefail

cd "$(dirname "$0")/../rust"

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q (default SIMD dispatch)"
cargo test -q

# The whole suite again with the lane layer forced to the scalar reference:
# every parity test now compares scalar-vs-scalar (trivially green) but the
# *dispatched* kernels, drivers and serving paths all run on the scalar
# backend — any result that differs between the two runs is a bit-identity
# violation in a SIMD port (see ARCHITECTURE.md "SIMD dispatch"). Skipped
# when the host has no wide backend (x86-64 without AVX2, non-aarch64):
# there the default run already dispatched scalar everywhere.
if grep -qi 'avx2' /proc/cpuinfo 2>/dev/null \
    || [[ "$(uname -m)" == "aarch64" || "$(uname -m)" == "arm64" ]]; then
    echo "==> STARS_SIMD=scalar cargo test -q (forced-scalar backend)"
    STARS_SIMD=scalar cargo test -q
else
    echo "==> forced-scalar test run skipped (detected backend is already scalar)"
fi

# The quantized tier's dedicated gates, pinned to the scalar backend
# regardless of host detection: the int8 parity suite and the quantized
# serve-integration tests (recall floor + worker invariance). Cheap and
# targeted — the quantized path's first documented parity *relaxation*
# must never silently widen into a backend dependence (see ARCHITECTURE.md
# "Quantized scoring tier").
echo "==> STARS_SIMD=scalar quantized-tier gates (quant_parity + serve_integration quant)"
STARS_SIMD=scalar cargo test -q --test quant_parity
STARS_SIMD=scalar cargo test -q --test serve_integration quantized

# Fault-injection gates. The suite's tests pin their plans explicitly
# (mutating the env races across parallel test threads), so the suite is
# run under two fixed STARS_FAULTS schedules to prove the env var is
# harmless in its presence — and the CLI build below, whose cluster *does*
# read the env, proves the end-to-end wiring: parse → active schedule →
# recovery → a successful build. Two different seeds so the schedule
# coverage isn't a single draw.
echo "==> STARS_FAULTS fault-injection gates (two fixed seeds)"
STARS_FAULTS="seed=1,crash=0.2,delay=0.1:20,corrupt=0.3,max_failures=2" \
    cargo test -q --test fault_injection
STARS_FAULTS="seed=40,crash=0.35,delay=0.05:10,corrupt=0.15,max_failures=3" \
    cargo test -q --test fault_injection
echo "==> STARS_FAULTS end-to-end env wiring (CLI build under faults)"
STARS_FAULTS="seed=1,crash=0.2,delay=0.1:20,corrupt=0.3,max_failures=2" \
    ./target/release/stars build --dataset random --n 2000 --r 4 \
    --threshold 0.5 --join shuffle >/dev/null

# Sharded serving gates. `tests/shard_parity.rs` (inside the suites above)
# proves scatter-gather answers bit-identical to single-shard serving; here
# the end-to-end CLI wiring is gated: --shards 1 keeps the single-engine
# path, --shards 4 serves through the fence-partitioned engine (with
# --tenants exercising the per-tenant QPS caps through the front door), and
# one forced-scalar 4-shard pass pins shard invariance to the scalar
# backend too.
echo "==> sharded serve gates (--shards 1, --shards 4 + tenants, scalar 4-shard)"
./target/release/stars serve --dataset random --n 2000 --r 4 \
    --threshold 0.5 --queries 20 --k 5 --shards 1 >/dev/null
./target/release/stars serve --dataset random --n 2000 --r 4 \
    --threshold 0.5 --queries 20 --k 5 --inserts 50 --shards 4 \
    --queue-limit 8 --tenants 0.001:2 >/dev/null
STARS_SIMD=scalar ./target/release/stars serve --dataset random --n 2000 \
    --r 4 --threshold 0.5 --queries 20 --k 5 --shards 4 >/dev/null

# Durable serve kill-and-restart gate (see ARCHITECTURE.md "Durability &
# crash recovery"). tests/durability.rs proves crash-point bit-identity at
# the store API level inside the suites above; this gates the *process*
# contract end to end. Run A serves clean over a state dir and reports a
# results_digest. Run B, over its own dir, gets a STARS_FAULTS crash
# schedule: the serve loop tears the WAL mid-append at the insert midpoint
# and dies (exit 3). The restarted process (faults unset) must recover from
# snapshot + WAL-suffix replay, finish the schedule, and report the same
# digest as the never-crashed run — for the exact and quantized tiers.
DUR_TMP="$(mktemp -d)"
trap 'rm -rf "$DUR_TMP"' EXIT
digest_of() { sed -n 's/.*"results_digest": *"\([0-9a-f]*\)".*/\1/p' "$1"; }
echo "==> durable serve kill-and-restart gate (exact + quantized)"
for MODE in exact quant; do
    QFLAG=""
    [[ "$MODE" == "quant" ]] && QFLAG="--quantized"
    ./target/release/stars serve --dataset random --n 2000 --r 4 \
        --threshold 0.5 --queries 20 --k 5 --inserts 40 --seal-limit 8 \
        --state-dir "$DUR_TMP/clean-$MODE" $QFLAG > "$DUR_TMP/clean-$MODE.json"
    set +e
    STARS_FAULTS="seed=1,crash=1.0,max_failures=1" \
        ./target/release/stars serve --dataset random --n 2000 --r 4 \
        --threshold 0.5 --queries 20 --k 5 --inserts 40 --seal-limit 8 \
        --state-dir "$DUR_TMP/crash-$MODE" $QFLAG >/dev/null 2>&1
    CODE=$?
    set -e
    if [[ "$CODE" != "3" ]]; then
        echo "durability gate ($MODE): expected injected crash (exit 3), got $CODE"
        exit 1
    fi
    ./target/release/stars serve --dataset random --n 2000 --r 4 \
        --threshold 0.5 --queries 20 --k 5 --inserts 40 --seal-limit 8 \
        --state-dir "$DUR_TMP/crash-$MODE" $QFLAG > "$DUR_TMP/recovered-$MODE.json"
    CLEAN="$(digest_of "$DUR_TMP/clean-$MODE.json")"
    RECOVERED="$(digest_of "$DUR_TMP/recovered-$MODE.json")"
    if [[ -z "$CLEAN" || "$CLEAN" != "$RECOVERED" ]]; then
        echo "durability gate ($MODE): digest mismatch (clean=$CLEAN recovered=$RECOVERED)"
        exit 1
    fi
    grep -q '"recovered": true' "$DUR_TMP/recovered-$MODE.json" || {
        echo "durability gate ($MODE): restart did not report recovered=true"
        exit 1
    }
done

# Observability gates (see ARCHITECTURE.md "Observability" and
# EXPERIMENTS.md §Observability). The tracing/metrics layer's own
# bit-identity and span-shape tests run inside the suites above; here the
# *end-to-end env wiring* is gated the same way as STARS_FAULTS: a CLI
# build + serve under STARS_TRACE must leave an NDJSON file whose every
# line parses back through the repo's own util::json (`stars
# trace-check`), a --metrics-out serve must leave a Prometheus-text
# snapshot behind, and the checked-in BENCH_*.json artifacts must carry
# the schema_version/data_status/simd_backend envelope.
OBS_TMP="$(mktemp -d)"
trap 'rm -rf "$OBS_TMP" "$DUR_TMP"' EXIT
echo "==> STARS_TRACE end-to-end env wiring (CLI build+serve, trace-check)"
STARS_TRACE="$OBS_TMP/trace.ndjson" STARS_TRACE_SAMPLE=1 \
    ./target/release/stars serve --dataset random --n 2000 --r 4 \
    --threshold 0.5 --queries 20 --k 5 \
    --metrics-out "$OBS_TMP/metrics.prom" --metrics-every 0.1 >/dev/null
./target/release/stars trace-check "$OBS_TMP/trace.ndjson"
echo "==> Prometheus snapshot sanity (--metrics-out)"
grep -q '# TYPE' "$OBS_TMP/metrics.prom"
grep -q 'stars_serve_query_latency_us' "$OBS_TMP/metrics.prom"
echo "==> BENCH_*.json envelope gate (bench-check)"
../scripts/check_bench_schema.sh

echo "==> cargo fmt --check"
cargo fmt --check

echo "==> cargo clippy -D warnings"
cargo clippy --all-targets -- -D warnings

echo "==> cargo doc --no-deps (rustdoc gate, warnings are errors)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "==> cargo bench --no-run (bench compile check)"
cargo bench --no-run

if [[ "${1:-}" == "--bench" ]]; then
    echo "==> cargo bench --bench microbench (writes ../BENCH_scoring.json)"
    cargo bench --bench microbench
    echo "==> cargo bench --bench sketchbench (writes ../BENCH_sketch.json)"
    cargo bench --bench sketchbench
    echo "==> cargo bench --bench servebench (writes ../BENCH_serve.json)"
    cargo bench --bench servebench
fi

echo "CI OK"
