#!/usr/bin/env bash
# CI entry point: tier-1 (build + tests) plus formatting and lint gates.
#
#   scripts/ci.sh          # tier-1 + fmt + clippy
#   scripts/ci.sh --bench  # also regenerate BENCH_scoring.json (slow)
#
# The perf trajectory is tracked via BENCH_scoring.json at the repo root,
# emitted by `cargo bench --bench microbench` (see EXPERIMENTS.md §Perf).
set -euo pipefail

cd "$(dirname "$0")/../rust"

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "==> cargo fmt --check"
cargo fmt --check

echo "==> cargo clippy -D warnings"
cargo clippy --all-targets -- -D warnings

if [[ "${1:-}" == "--bench" ]]; then
    echo "==> cargo bench --bench microbench (writes ../BENCH_scoring.json)"
    cargo bench --bench microbench
fi

echo "CI OK"
