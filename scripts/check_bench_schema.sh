#!/usr/bin/env bash
# BENCH_*.json envelope gate: every checked-in bench artifact must parse
# and carry the three envelope keys — `schema_version` (non-empty),
# `data_status` (provenance: measured vs PROJECTED) and `simd_backend` —
# so a bench emitter can never silently drop the provenance machinery
# (EXPERIMENTS.md §The BENCH_*.json convention). The actual validation
# lives in the repo's own binary (`stars bench-check`, built on the
# zero-dependency util::json parser), keeping this script free of
# external JSON tooling.
#
#   scripts/check_bench_schema.sh            # checks the three root files
#   scripts/check_bench_schema.sh FILE...    # checks the given files
set -euo pipefail

ROOT="$(cd "$(dirname "$0")/.." && pwd)"
BIN="$ROOT/rust/target/release/stars"

if [[ ! -x "$BIN" ]]; then
    echo "==> building release binary for bench-check"
    (cd "$ROOT/rust" && cargo build --release)
fi

if [[ $# -gt 0 ]]; then
    FILES=("$@")
else
    FILES=(
        "$ROOT/BENCH_scoring.json"
        "$ROOT/BENCH_sketch.json"
        "$ROOT/BENCH_serve.json"
    )
fi

"$BIN" bench-check "${FILES[@]}"
