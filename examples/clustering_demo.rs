//! Clustering demo: the digits (MNIST stand-in) dataset end to end —
//! graph building, average Affinity clustering (Figure 4), and the
//! single-linkage 2-approximation of Theorem 2.5.
//!
//! Run: `cargo run --release --example clustering_demo [n]` (default 10000)

use stars::clustering::{affinity_cluster_to_k, single_linkage_k, sweep_components, v_measure};
use stars::data::synth;
use stars::graph::Csr;
use stars::lsh::SimHash;
use stars::sim::{CosineSim, CountingSim};
use stars::stars::{Algorithm, BuildParams, StarsBuilder};

fn main() {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(10_000);
    let ds = synth::digits(n, 42);
    println!(
        "digits dataset: {} points, dim {}, {} classes",
        ds.len(),
        ds.dim(),
        ds.num_classes()
    );

    // Build graphs with Stars and non-Stars; compare clustering quality.
    let family = SimHash::new(ds.dim(), 12, 7);
    for algo in [Algorithm::Lsh, Algorithm::LshStars] {
        let sim = CountingSim::new(CosineSim);
        let out = StarsBuilder::new(&ds)
            .similarity(&sim)
            .hash(&family)
            .params(
                BuildParams::threshold_mode(algo)
                    .sketches(100)
                    .threshold(0.5),
            )
            .build();
        let graph = out.graph.filter_weight(0.5);
        let level = affinity_cluster_to_k(&graph, ds.num_classes());
        let vm = v_measure(&level.labels, &ds.labels);
        println!(
            "{:<10} {:>12} comparisons  {:>9} edges  {} clusters  V-Measure {:.3}",
            algo.name(),
            out.report.comparisons,
            graph.num_edges(),
            level.clusters,
            vm.v
        );

        if algo == Algorithm::LshStars {
            // Theorem 2.5: single-linkage over the spanner.
            let k = ds.num_classes();
            let (labels, cost) = single_linkage_k(&out.graph, k);
            let vm_sl = v_measure(&labels, &ds.labels);
            println!(
                "  single-linkage k={k}: objective (max cross-cluster sim) {:.3}, V-Measure {:.3}",
                cost, vm_sl.v
            );
            // Component sweep (the geometric-threshold construction).
            println!("  component sweep over the spanner:");
            for r in [0.4f32, 0.5, 0.6, 0.7, 0.8] {
                println!("    r={r}: {} components", sweep_components(&out.graph, r));
            }
            let csr = Csr::new(&out.graph);
            println!(
                "  graph degrees: mean {:.1}, max {}",
                stars::graph::stats::degree_stats(&csr).mean,
                csr.max_degree()
            );
        }
    }
}
