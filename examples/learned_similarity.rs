//! Learned similarity demo: the paper's motivating scenario.
//!
//! A neural similarity model (trained and frozen into an HLO artifact at
//! build time) is 5-10x costlier per comparison than the cosine/Jaccard
//! mixture. Stars reduces comparisons ~10x, which translates directly into
//! total-time savings — making the expensive, higher-quality measure
//! affordable (paper §5 "Effect of the similarity function", Tables 1-2).
//!
//! Requires `make artifacts`. Run:
//! `cargo run --release --example learned_similarity [n]` (default 3000)

use stars::bench::{fmt_count, Table};
use stars::clustering::{affinity_cluster_to_k, v_measure};
use stars::coordinator::driver::make_measure;
use stars::coordinator::job::MeasureSpec;
use stars::data::synth;
use stars::lsh::MixtureHash;
use stars::sim::Similarity;
use stars::stars::{Algorithm, BuildParams, StarsBuilder};

fn main() -> stars::Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(3_000);
    // Same recipe seed the model was trained on (artifacts/meta.json).
    let meta = stars::runtime::ArtifactMeta::load(&stars::runtime::ArtifactMeta::default_dir())?;
    let seed = meta
        .raw
        .get("recipe_seed")
        .and_then(|v| v.as_usize())
        .unwrap_or(42) as u64;
    let ds = synth::products(n, &synth::ProductsParams::default(), seed);
    println!(
        "products-{n}: {} classes; learned model holdout AUC {:.3}\n",
        ds.num_classes(),
        meta.raw
            .get("learned_sim")
            .and_then(|e| e.get("auc"))
            .and_then(|v| v.as_f64())
            .unwrap_or(f64::NAN)
    );

    let family = MixtureHash::new(ds.dim(), 12, 31);
    let mut table = Table::new(&[
        "measure", "algorithm", "comparisons", "total(s)", "edges", "vmeasure",
    ]);
    for mspec in [MeasureSpec::Mixture, MeasureSpec::Learned] {
        let measure = make_measure(mspec)?;
        let threshold = if mspec == MeasureSpec::Learned { 0.5 } else { 0.4 };
        for algo in [Algorithm::Lsh, Algorithm::LshStars] {
            let counting = Counting::new(measure.as_ref());
            let out = StarsBuilder::new(&ds)
                .similarity(&counting)
                .hash(&family)
                .params(
                    BuildParams::threshold_mode(algo)
                        .sketches(25)
                        .threshold(threshold),
                )
                .build();
            let graph = out.graph.filter_weight(threshold);
            let level = affinity_cluster_to_k(&graph, ds.num_classes());
            let vm = v_measure(&level.labels, &ds.labels);
            table.row(vec![
                mspec.name().into(),
                algo.name().into(),
                fmt_count(out.report.comparisons),
                format!("{:.2}", out.report.total_time),
                fmt_count(graph.num_edges() as u64),
                format!("{:.3}", vm.v),
            ]);
        }
    }
    table.print();
    println!("\n(the learned rows pay ~an order of magnitude more per comparison;");
    println!(" Stars keeps their total time in the same league as mixture non-Stars)");
    Ok(())
}

struct Counting<'a> {
    inner: &'a dyn Similarity,
    count: std::sync::atomic::AtomicU64,
}

impl<'a> Counting<'a> {
    fn new(inner: &'a dyn Similarity) -> Self {
        Counting {
            inner,
            count: Default::default(),
        }
    }
}

impl Similarity for Counting<'_> {
    fn sim(&self, ds: &stars::data::Dataset, i: usize, j: usize) -> f32 {
        self.count
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.inner.sim(ds, i, j)
    }

    fn sim_batch(
        &self,
        ds: &stars::data::Dataset,
        leader: usize,
        candidates: &[u32],
        out: &mut Vec<f32>,
    ) {
        self.count
            .fetch_add(candidates.len() as u64, std::sync::atomic::Ordering::Relaxed);
        self.inner.sim_batch(ds, leader, candidates, out);
    }

    fn name(&self) -> &'static str {
        self.inner.name()
    }
}
