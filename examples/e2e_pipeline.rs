//! End-to-end system driver (the EXPERIMENTS.md §E2E run).
//!
//! Exercises every layer on one realistic workload — the Amazon2m stand-in:
//!
//! 1. generate the products dataset (hybrid embedding + co-purchase sets);
//! 2. build graphs with all four LSH algorithms (mixture similarity),
//!    through the simulated AMPC cluster with cost accounting;
//! 3. score a Stars graph with the **learned similarity model executing via
//!    PJRT from the rust hot path** (L1/L2 artifacts), proving the three
//!    layers compose;
//! 4. evaluate: comparisons, recall vs brute-force ground truth, V-Measure
//!    of Affinity clustering;
//! 5. print the report and write results/e2e_pipeline.json.
//!
//! Run: `cargo run --release --example e2e_pipeline [n]` (default 20000)

use stars::clustering::{affinity_cluster_to_k, v_measure};
use stars::coordinator::driver::{make_family, make_measure};
use stars::coordinator::job::{DatasetSpec, FamilySpec, MeasureSpec};
use stars::eval::recall::{sample_queries, threshold_recall};
use stars::graph::Csr;
use stars::sim::Similarity;
use stars::stars::{allpair, Algorithm, BuildParams, StarsBuilder};
use stars::util::json::Json;

fn main() -> stars::Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(20_000);
    let seed = 42u64;
    let threshold = 0.4f32;
    let workers = stars::util::pool::default_workers();
    println!("=== Stars end-to-end pipeline (products-{n}, {workers} workers) ===\n");

    // ---- 1. Dataset ----
    let t0 = std::time::Instant::now();
    let spec = DatasetSpec::Products { n };
    let ds = spec.realize(seed)?;
    println!(
        "[1] dataset: {} points, dim {}, {} classes, generated in {:.1}s",
        ds.len(),
        ds.dim(),
        ds.num_classes(),
        t0.elapsed().as_secs_f64()
    );

    // ---- 2. Graph building with all four algorithms ----
    let measure = make_measure(MeasureSpec::Mixture)?;
    let mut rows = Vec::new();
    let mut stars_graph = None;
    println!("\n[2] graph building (mixture similarity, R=25):");
    for algo in [
        Algorithm::Lsh,
        Algorithm::LshStars,
        Algorithm::SortingLsh,
        Algorithm::SortingLshStars,
    ] {
        let sorting = matches!(algo, Algorithm::SortingLsh | Algorithm::SortingLshStars);
        let family = make_family(FamilySpec::default_for(&spec, sorting), ds.dim(), seed ^ 1);
        let params = if sorting {
            BuildParams::knn_mode(algo).sketches(25)
        } else {
            BuildParams::threshold_mode(algo)
                .sketches(25)
                .threshold(threshold)
        };
        let counting = CountingDyn::new(measure.as_ref());
        let out = StarsBuilder::new(&ds)
            .similarity(&counting)
            .hash(family.as_ref())
            .params(params)
            .workers(workers)
            .build();
        println!(
            "    {:<18} {:>14} comparisons  {:>9} edges  total {:>7.2}s  real {:>6.2}s",
            algo.name(),
            stars::bench::fmt_count(out.report.comparisons),
            stars::bench::fmt_count(out.graph.num_edges() as u64),
            out.report.total_time,
            out.report.real_time,
        );
        rows.push(Json::obj(vec![
            ("algorithm", Json::from(algo.name())),
            ("comparisons", Json::from(out.report.comparisons)),
            ("edges", Json::from(out.graph.num_edges())),
            ("total_time_s", Json::from(out.report.total_time)),
            ("real_time_s", Json::from(out.report.real_time)),
        ]));
        if algo == Algorithm::LshStars {
            stars_graph = Some(out.graph);
        }
    }
    let stars_graph = stars_graph.unwrap();

    // ---- 3. Learned similarity via PJRT (L1+L2 -> L3 composition) ----
    println!("\n[3] learned similarity through PJRT (AOT artifacts):");
    let learned_json = match make_measure(MeasureSpec::Learned) {
        Err(e) => {
            println!("    SKIPPED (run `make artifacts`): {e}");
            Json::Null
        }
        Ok(learned) => {
            // Build a Stars graph where every similarity evaluation is a
            // batched PJRT dispatch of the neural model.
            let family = make_family(FamilySpec::default_for(&spec, false), ds.dim(), seed ^ 2);
            let counting = CountingDyn::new(learned.as_ref());
            let sub = ds.take(4000); // learned scoring is ~10x costlier
            let t = std::time::Instant::now();
            let out = StarsBuilder::new(&sub)
                .similarity(&counting)
                .hash(family.as_ref())
                .params(
                    BuildParams::threshold_mode(Algorithm::LshStars)
                        .sketches(10)
                        .threshold(0.5),
                )
                .workers(workers)
                .build();
            let level = affinity_cluster_to_k(&out.graph.filter_weight(0.5), sub.num_classes());
            let vm = v_measure(&level.labels, &sub.labels);
            println!(
                "    lsh+stars/learned: {} comparisons, {} edges, {:.1}s wall, V-Measure {:.3}",
                stars::bench::fmt_count(out.report.comparisons),
                stars::bench::fmt_count(out.graph.num_edges() as u64),
                t.elapsed().as_secs_f64(),
                vm.v
            );
            Json::obj(vec![
                ("comparisons", Json::from(out.report.comparisons)),
                ("edges", Json::from(out.graph.num_edges())),
                ("vmeasure", Json::from(vm.v)),
                ("n", Json::from(sub.len())),
            ])
        }
    };

    // ---- 4. Recall vs brute-force ground truth ----
    println!("\n[4] recall vs brute force (threshold {threshold}):");
    let cluster = stars::ampc::Cluster::new(workers);
    let eval_n = ds.len().min(6000);
    let eval_ds = ds.take(eval_n);
    let truth = allpair::exact_threshold_neighbors(&eval_ds, measure.as_ref(), threshold, &cluster);
    // Rebuild on the eval subset so ground truth matches.
    let family = make_family(FamilySpec::default_for(&spec, false), ds.dim(), seed ^ 1);
    let counting = CountingDyn::new(measure.as_ref());
    let out = StarsBuilder::new(&eval_ds)
        .similarity(&counting)
        .hash(family.as_ref())
        .params(
            BuildParams::threshold_mode(Algorithm::LshStars)
                .sketches(100)
                .threshold(threshold),
        )
        .workers(workers)
        .build();
    let csr = Csr::new(&out.graph);
    let queries = sample_queries(eval_ds.len(), 500, seed ^ 3);
    let rec = threshold_recall(&csr, &truth, &queries, threshold, threshold * 0.99);
    println!(
        "    1-hop {:.3}   2-hop {:.3}   2-hop relaxed {:.3}   ({} queries)",
        rec.one_hop, rec.two_hop, rec.two_hop_relaxed, rec.queries
    );

    // ---- 5. Clustering quality ----
    println!("\n[5] Affinity clustering V-Measure:");
    let level = affinity_cluster_to_k(&stars_graph.filter_weight(threshold), ds.num_classes());
    let vm = v_measure(&level.labels, &ds.labels);
    println!(
        "    lsh+stars graph: {} clusters, V-Measure {:.3} (homogeneity {:.3}, completeness {:.3})",
        level.clusters, vm.v, vm.homogeneity, vm.completeness
    );

    let doc = Json::obj(vec![
        ("example", Json::from("e2e_pipeline")),
        ("n", Json::from(n)),
        ("build_rows", Json::Arr(rows)),
        ("learned", learned_json),
        (
            "recall",
            Json::obj(vec![
                ("one_hop", Json::from(rec.one_hop)),
                ("two_hop", Json::from(rec.two_hop)),
                ("two_hop_relaxed", Json::from(rec.two_hop_relaxed)),
            ]),
        ),
        ("vmeasure", Json::from(vm.v)),
    ]);
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/e2e_pipeline.json", doc.to_pretty())?;
    println!("\nwrote results/e2e_pipeline.json");
    Ok(())
}

/// Counting wrapper over a borrowed dyn measure.
struct CountingDyn<'a> {
    inner: &'a dyn Similarity,
    count: std::sync::atomic::AtomicU64,
}

impl<'a> CountingDyn<'a> {
    fn new(inner: &'a dyn Similarity) -> Self {
        CountingDyn {
            inner,
            count: Default::default(),
        }
    }
}

impl Similarity for CountingDyn<'_> {
    fn sim(&self, ds: &stars::data::Dataset, i: usize, j: usize) -> f32 {
        self.count
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.inner.sim(ds, i, j)
    }

    fn sim_batch(
        &self,
        ds: &stars::data::Dataset,
        leader: usize,
        candidates: &[u32],
        out: &mut Vec<f32>,
    ) {
        self.count
            .fetch_add(candidates.len() as u64, std::sync::atomic::Ordering::Relaxed);
        self.inner.sim_batch(ds, leader, candidates, out);
    }

    fn name(&self) -> &'static str {
        self.inner.name()
    }
}
