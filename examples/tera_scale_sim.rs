//! "Tera-scale" simulation: the Table 3 experiment at the largest size this
//! box handles — Random1M (and Random10M with `--big`), 100-mode GMM,
//! R=25 sketches, degree threshold 250.
//!
//! The paper's claim reproduced here: Stars variants do within a small
//! constant of the retained-edge count in comparisons, while non-Stars
//! algorithms burn 10-100x more; total running time follows comparisons.
//!
//! Run: `cargo run --release --example tera_scale_sim [--big] [--n N]`

use stars::bench::{fmt_count, Table};
use stars::data::synth;
use stars::sim::{CosineSim, CountingSim};
use stars::stars::{Algorithm, BuildParams, StarsBuilder};
use stars::util::args::Args;

fn main() {
    let args = Args::from_env();
    let n: usize = if args.flag("big") {
        10_000_000
    } else {
        args.get_parsed_or("n", 1_000_000usize)
    };
    let workers = stars::util::pool::default_workers();
    println!("generating random-{n} (100-mode GMM, dim 100) ...");
    let t = std::time::Instant::now();
    let ds = synth::gaussian_mixture(n, 100, 100, 0.1, 42);
    println!("generated in {:.1}s\n", t.elapsed().as_secs_f64());

    let mut table = Table::new(&[
        "algorithm",
        "comparisons",
        "edges",
        "total(s)",
        "real(s)",
        "rel total",
    ]);
    let mut base_total = None;
    for algo in [
        Algorithm::Lsh,
        Algorithm::SortingLsh,
        Algorithm::LshStars,
        Algorithm::SortingLshStars,
    ] {
        let sorting = matches!(algo, Algorithm::SortingLsh | Algorithm::SortingLshStars);
        let family = stars::lsh::SimHash::new(100, if sorting { 30 } else { 16 }, 7);
        let params = if sorting {
            BuildParams::knn_mode(algo).sketches(25).degree_cap(250)
        } else {
            BuildParams::threshold_mode(algo)
                .sketches(25)
                .threshold(0.5)
                .degree_cap(250)
        };
        let sim = CountingSim::new(CosineSim);
        let out = StarsBuilder::new(&ds)
            .similarity(&sim)
            .hash(&family)
            .params(params)
            .workers(workers)
            .build();
        let base = *base_total.get_or_insert(out.report.total_time);
        table.row(vec![
            algo.name().into(),
            fmt_count(out.report.comparisons),
            fmt_count(out.graph.num_edges() as u64),
            format!("{:.1}", out.report.total_time),
            format!("{:.1}", out.report.real_time),
            format!("{:.3}", out.report.total_time / base),
        ]);
    }
    table.print();
    println!("\n(paper Table 3 shape: lsh ~ 1.0, stars variants ~ 0.01-0.2)");
}
