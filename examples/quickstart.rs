//! Quickstart: build a two-hop spanner with LSH+Stars on a synthetic
//! Gaussian-mixture dataset and inspect the result.
//!
//! Run: `cargo run --release --example quickstart`

use stars::data::synth;
use stars::graph::Csr;
use stars::lsh::SimHash;
use stars::sim::{CosineSim, CountingSim};
use stars::stars::{Algorithm, BuildParams, StarsBuilder};

fn main() {
    // 1. A dataset: 20k points from a 100-mode GMM in 100 dimensions (the
    //    paper's Random1B recipe, scaled down).
    let ds = synth::gaussian_mixture(20_000, 100, 100, 0.1, 42);
    println!("dataset: {} points, dim {}", ds.len(), ds.dim());

    // 2. A similarity measure (with comparison counting) and an LSH family.
    let sim = CountingSim::new(CosineSim);
    let family = SimHash::new(ds.dim(), 16, 7);

    // 3. Build with Stars 1 (LSH bucketing + star graphs per bucket).
    let out = StarsBuilder::new(&ds)
        .similarity(&sim)
        .hash(&family)
        .params(
            BuildParams::threshold_mode(Algorithm::LshStars)
                .sketches(25) // R
                .leaders(25) // s
                .threshold(0.5), // r1
        )
        .build();

    println!(
        "built {} edges with {} comparisons ({}x fewer than brute force)",
        out.graph.num_edges(),
        out.report.comparisons,
        (ds.len() as u64 * (ds.len() as u64 - 1) / 2) / out.report.comparisons.max(1)
    );
    println!(
        "total time {:.2}s across {} workers, real time {:.2}s",
        out.report.total_time, out.report.workers, out.report.real_time
    );

    // 4. Inspect the graph.
    let csr = Csr::new(&out.graph);
    let stats = stars::graph::stats::degree_stats(&csr);
    println!(
        "degrees: mean {:.1}, max {}, isolated {}",
        stats.mean, stats.max, stats.isolated
    );

    // 5. Two-hop neighborhoods are the point: sample one node and count
    //    reachable similar points at 1 vs 2 hops.
    let p = 0u32;
    let h1 = stars::graph::two_hop::one_hop_set(&csr, p, 0.5);
    let h2 = stars::graph::two_hop::two_hop_set(&csr, p, 0.5);
    println!("node {p}: {} direct neighbors, {} within two hops", h1.len(), h2.len());
}
